//! The routing policy, pure and unit-tested in isolation: given a
//! snapshot of the lane pool, pick where one batch goes — plus the
//! EDF retry order for deferred batches.  The stateful half (pins,
//! deferred queue, counters) lives in [`super::scheduler`]; this module
//! is only the decision functions, so every invariant can be pinned by
//! a table-driven test with no threads involved.

use std::cmp::Ordering;

/// One routing decision.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub(crate) enum Route {
    Lane(usize),
    /// Every capable lane is saturated (or the pinned lane is): hold
    /// the batch and retry when a lane drains.
    Defer,
}

/// A lane as the routing function sees it.
#[derive(Debug, Clone, Copy)]
pub(crate) struct LaneView {
    pub capable: bool,
    pub depth: usize,
    pub cost_s: f64,
}

/// Pick a lane for one batch.  `pinned` is the lane currently holding
/// the network's in-flight batches (the ordering invariant), `max_depth`
/// the backpressure bound.
///
/// Priority: pinned lane (or defer) → cheapest *idle* capable lane →
/// shallowest-queue capable lane (cost breaks ties) → defer.
pub(crate) fn choose_lane(
    lanes: &[LaneView],
    pinned: Option<usize>,
    max_depth: usize,
) -> Route {
    if let Some(pin) = pinned {
        // ordering beats latency: the network follows its lane or waits
        return if lanes[pin].depth < max_depth {
            Route::Lane(pin)
        } else {
            Route::Defer
        };
    }
    let open = || {
        lanes
            .iter()
            .enumerate()
            .filter(|(_, l)| l.capable && l.depth < max_depth)
    };
    let idle_best = open()
        .filter(|(_, l)| l.depth == 0)
        .min_by(|(_, a), (_, b)| a.cost_s.total_cmp(&b.cost_s));
    if let Some((i, _)) = idle_best {
        return Route::Lane(i);
    }
    match open().min_by(|(_, a), (_, b)| {
        a.depth.cmp(&b.depth).then(a.cost_s.total_cmp(&b.cost_s))
    }) {
        Some((i, _)) => Route::Lane(i),
        None => Route::Defer,
    }
}

/// A deferred batch as the retry-ordering function sees it.
#[derive(Debug, Clone, Copy)]
pub(crate) struct DeferredView {
    /// Dense index of the batch's network (grouping key).
    pub network: usize,
    /// Slack in seconds at retry time — the earliest deadline aboard
    /// minus the batch's predicted cost, signed (negative = already
    /// infeasible); `None` = best-effort.
    pub slack_s: Option<f64>,
    /// Defer-queue admission sequence (monotone per scheduler).
    pub seq: u64,
}

/// Retry order for the deferred queue: **networks** by their most
/// urgent pending batch's slack (EDF; best-effort networks last,
/// admission sequence breaking ties), **batches within one network**
/// strictly by admission sequence — per-network submission order is an
/// ordering invariant EDF must not break (a network's responses resolve
/// in submission order; see DESIGN.md §Deadline scheduling).
pub(crate) fn retry_order(views: &[DeferredView]) -> Vec<usize> {
    let n_nets = views.iter().map(|v| v.network + 1).max().unwrap_or(0);
    // per network: (min slack, min seq) — urgency of its head batch
    let mut urgency: Vec<(f64, u64)> = vec![(f64::INFINITY, u64::MAX); n_nets];
    for v in views {
        let u = &mut urgency[v.network];
        u.0 = u.0.min(v.slack_s.unwrap_or(f64::INFINITY));
        u.1 = u.1.min(v.seq);
    }
    let mut order: Vec<usize> = (0..views.len()).collect();
    order.sort_by(|&a, &b| {
        let (va, vb) = (&views[a], &views[b]);
        if va.network == vb.network {
            return va.seq.cmp(&vb.seq);
        }
        let (ua, ub) = (urgency[va.network], urgency[vb.network]);
        match ua.0.total_cmp(&ub.0) {
            Ordering::Equal => ua.1.cmp(&ub.1),
            other => other,
        }
    });
    order
}

#[cfg(test)]
mod tests {
    use super::*;

    fn lv(capable: bool, depth: usize, cost_s: f64) -> LaneView {
        LaneView {
            capable,
            depth,
            cost_s,
        }
    }

    #[test]
    fn cheapest_idle_capable_lane_wins() {
        let lanes = [lv(true, 0, 3.0), lv(true, 0, 1.0), lv(true, 0, 2.0)];
        assert_eq!(choose_lane(&lanes, None, 4), Route::Lane(1));
    }

    #[test]
    fn idle_beats_cheaper_but_busy() {
        // lane 0 is cheaper but has queued work; lane 1 is idle
        let lanes = [lv(true, 2, 1.0), lv(true, 0, 5.0)];
        assert_eq!(choose_lane(&lanes, None, 4), Route::Lane(1));
    }

    #[test]
    fn no_idle_lane_takes_shallowest_queue_then_cost() {
        let lanes = [lv(true, 2, 1.0), lv(true, 1, 9.0), lv(true, 1, 2.0)];
        assert_eq!(choose_lane(&lanes, None, 4), Route::Lane(2));
    }

    #[test]
    fn incapable_lanes_are_never_chosen() {
        let lanes = [lv(false, 0, 0.001), lv(true, 3, 9.0)];
        assert_eq!(choose_lane(&lanes, None, 4), Route::Lane(1));
    }

    #[test]
    fn saturated_pool_defers() {
        let lanes = [lv(true, 4, 1.0), lv(false, 0, 1.0)];
        assert_eq!(choose_lane(&lanes, None, 4), Route::Defer);
    }

    #[test]
    fn pin_overrides_cost_and_defers_when_full() {
        // ordering invariant: in-flight network follows its lane even
        // though lane 0 is idle and cheaper…
        let lanes = [lv(true, 0, 0.001), lv(true, 1, 9.0)];
        assert_eq!(choose_lane(&lanes, Some(1), 4), Route::Lane(1));
        // …and waits rather than jump lanes when it is saturated
        let lanes = [lv(true, 0, 0.001), lv(true, 4, 9.0)];
        assert_eq!(choose_lane(&lanes, Some(1), 4), Route::Defer);
    }

    fn dv(network: usize, slack_s: Option<f64>, seq: u64) -> DeferredView {
        DeferredView {
            network,
            slack_s,
            seq,
        }
    }

    #[test]
    fn retry_order_is_edf_across_networks() {
        // network 1 is the most urgent (slack 2 ms), then 0, best-effort
        // network 2 last
        let views = [
            dv(0, Some(0.050), 0),
            dv(1, Some(0.002), 1),
            dv(2, None, 2),
        ];
        assert_eq!(retry_order(&views), vec![1, 0, 2]);
    }

    #[test]
    fn retry_order_keeps_per_network_submission_order() {
        // network 0's second batch carries a *tighter* deadline than its
        // first (a late urgent request) — EDF must not let it overtake
        // within the network, only raise the whole network's urgency
        let views = [
            dv(0, Some(0.040), 0),
            dv(1, Some(0.010), 1),
            dv(0, Some(0.001), 2),
        ];
        // network 0's urgency (0.001) beats network 1's (0.010), but its
        // batches still retry in admission order 0 → 2
        assert_eq!(retry_order(&views), vec![0, 2, 1]);
    }

    #[test]
    fn retry_order_negative_slack_sorts_first_and_ties_by_seq() {
        let views = [
            dv(0, Some(0.005), 0),
            dv(1, Some(-0.003), 1),
            dv(2, Some(0.005), 2),
        ];
        assert_eq!(retry_order(&views), vec![1, 0, 2]);
        let empty: [DeferredView; 0] = [];
        assert!(retry_order(&empty).is_empty());
    }
}
