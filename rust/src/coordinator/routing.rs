//! The routing policy, pure and unit-tested in isolation: given a
//! snapshot of the lane pool, pick where one batch goes.  The stateful
//! half (pins, deferred queue, counters) lives in [`super::scheduler`];
//! this module is only the decision function, so every invariant can be
//! pinned by a table-driven test with no threads involved.

/// One routing decision.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub(crate) enum Route {
    Lane(usize),
    /// Every capable lane is saturated (or the pinned lane is): hold
    /// the batch and retry when a lane drains.
    Defer,
}

/// A lane as the routing function sees it.
#[derive(Debug, Clone, Copy)]
pub(crate) struct LaneView {
    pub capable: bool,
    pub depth: usize,
    pub cost_s: f64,
}

/// Pick a lane for one batch.  `pinned` is the lane currently holding
/// the network's in-flight batches (the ordering invariant), `max_depth`
/// the backpressure bound.
///
/// Priority: pinned lane (or defer) → cheapest *idle* capable lane →
/// shallowest-queue capable lane (cost breaks ties) → defer.
pub(crate) fn choose_lane(
    lanes: &[LaneView],
    pinned: Option<usize>,
    max_depth: usize,
) -> Route {
    if let Some(pin) = pinned {
        // ordering beats latency: the network follows its lane or waits
        return if lanes[pin].depth < max_depth {
            Route::Lane(pin)
        } else {
            Route::Defer
        };
    }
    let open = || {
        lanes
            .iter()
            .enumerate()
            .filter(|(_, l)| l.capable && l.depth < max_depth)
    };
    let idle_best = open()
        .filter(|(_, l)| l.depth == 0)
        .min_by(|(_, a), (_, b)| a.cost_s.total_cmp(&b.cost_s));
    if let Some((i, _)) = idle_best {
        return Route::Lane(i);
    }
    match open().min_by(|(_, a), (_, b)| {
        a.depth.cmp(&b.depth).then(a.cost_s.total_cmp(&b.cost_s))
    }) {
        Some((i, _)) => Route::Lane(i),
        None => Route::Defer,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn lv(capable: bool, depth: usize, cost_s: f64) -> LaneView {
        LaneView {
            capable,
            depth,
            cost_s,
        }
    }

    #[test]
    fn cheapest_idle_capable_lane_wins() {
        let lanes = [lv(true, 0, 3.0), lv(true, 0, 1.0), lv(true, 0, 2.0)];
        assert_eq!(choose_lane(&lanes, None, 4), Route::Lane(1));
    }

    #[test]
    fn idle_beats_cheaper_but_busy() {
        // lane 0 is cheaper but has queued work; lane 1 is idle
        let lanes = [lv(true, 2, 1.0), lv(true, 0, 5.0)];
        assert_eq!(choose_lane(&lanes, None, 4), Route::Lane(1));
    }

    #[test]
    fn no_idle_lane_takes_shallowest_queue_then_cost() {
        let lanes = [lv(true, 2, 1.0), lv(true, 1, 9.0), lv(true, 1, 2.0)];
        assert_eq!(choose_lane(&lanes, None, 4), Route::Lane(2));
    }

    #[test]
    fn incapable_lanes_are_never_chosen() {
        let lanes = [lv(false, 0, 0.001), lv(true, 3, 9.0)];
        assert_eq!(choose_lane(&lanes, None, 4), Route::Lane(1));
    }

    #[test]
    fn saturated_pool_defers() {
        let lanes = [lv(true, 4, 1.0), lv(false, 0, 1.0)];
        assert_eq!(choose_lane(&lanes, None, 4), Route::Defer);
    }

    #[test]
    fn pin_overrides_cost_and_defers_when_full() {
        // ordering invariant: in-flight network follows its lane even
        // though lane 0 is idle and cheaper…
        let lanes = [lv(true, 0, 0.001), lv(true, 1, 9.0)];
        assert_eq!(choose_lane(&lanes, Some(1), 4), Route::Lane(1));
        // …and waits rather than jump lanes when it is saturated
        let lanes = [lv(true, 0, 0.001), lv(true, 4, 9.0)];
        assert_eq!(choose_lane(&lanes, Some(1), 4), Route::Defer);
    }
}
