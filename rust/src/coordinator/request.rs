//! Request/response types of the serving API.

use crate::tensor::Tensor;
use std::time::Instant;

pub type RequestId = u64;

/// One client request: "generate `n_images` samples from `network`".
#[derive(Debug, Clone)]
pub struct InferenceRequest {
    pub id: RequestId,
    pub network: String,
    pub n_images: usize,
    /// Latent seed (deterministic generation for reproducible tests).
    pub seed: u64,
    pub enqueued_at: Instant,
}

impl InferenceRequest {
    pub fn new(id: RequestId, network: &str, n_images: usize, seed: u64) -> Self {
        InferenceRequest {
            id,
            network: network.to_string(),
            n_images,
            seed,
            enqueued_at: Instant::now(),
        }
    }
}

/// Completed request with its generated images and serving telemetry.
#[derive(Debug)]
pub struct InferenceResponse {
    pub id: RequestId,
    /// `[n_images, C, H, W]` in [-1, 1].
    pub images: Tensor,
    /// End-to-end latency (enqueue → response), seconds.
    pub latency_s: f64,
    /// Wall time inside the numeric substrate, seconds.
    pub execute_s: f64,
    /// Batch bucket this request was served in.
    pub batch_size: usize,
    /// Lane/backend that served the batch (e.g. `fpga0`).
    pub backend: String,
    /// This request's share of the serving device's (simulated or
    /// measured) batch latency, seconds.
    pub device_time_s: f64,
    /// This request's share of the serving device's batch energy, J.
    pub energy_j: f64,
    /// Pool-global execution sequence of the serving batch — makes the
    /// per-network ordering guarantee observable (and testable).
    pub exec_seq: u64,
    /// Simulated edge-FPGA latency for the same work (annotation,
    /// independent of which backend actually served it).
    pub fpga_time_s: f64,
    /// Simulated edge-GPU latency for the same work at boost clock
    /// (annotation, independent of the serving backend).
    pub gpu_time_s: f64,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn request_records_enqueue_time() {
        let r = InferenceRequest::new(1, "mnist", 4, 42);
        assert_eq!(r.network, "mnist");
        assert!(r.enqueued_at.elapsed().as_secs_f64() < 1.0);
    }
}
