//! Request/response types of the serving API, built around the
//! [`RequestCtx`] every request carries from arrival to verdict.
//!
//! The context is created **once**, by whoever originates the request
//! (the workload layer stamps the *scheduled* arrival so generator lag
//! is charged to the system; the ad-hoc `serve` path stamps "now"), and
//! flows intact through intake → batching → routing → execution →
//! reply → telemetry.  Before this type existed each layer kept its own
//! fields (the batcher an enqueue `Instant`, the loadtest a scheduled
//! timestamp plus a lag correction, the executor a bare latent seed);
//! deadlines and priority classes could not exist because no single
//! struct survived the whole lifecycle.

use crate::telemetry::StageStamps;
use crate::tensor::ImageBlock;
use std::fmt;
use std::str::FromStr;
use std::time::{Duration, Instant};

pub type RequestId = u64;

/// Priority class of a request — the load-shedding axis.  Ordering
/// between requests is EDF (earliest deadline first); the class instead
/// controls *how early a request is shed* under overload: `Low` gives
/// up its admission budget first, `High` keeps the full budget and wins
/// EDF ties.  This keeps the low class starvation-free (its deadlines
/// still age into "earliest"), unlike strict priority queues.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash, Default)]
pub enum PriorityClass {
    High,
    #[default]
    Normal,
    Low,
}

impl PriorityClass {
    /// EDF tie-break rank (lower = served first at equal deadlines).
    pub fn rank(self) -> u8 {
        match self {
            PriorityClass::High => 0,
            PriorityClass::Normal => 1,
            PriorityClass::Low => 2,
        }
    }

    /// Fraction of the `admit_max_deferred` overload budget this class
    /// may use before being shed at intake (shed-early: the low class
    /// is turned away while the pool still has headroom for the rest).
    pub fn shed_fraction(self) -> f64 {
        match self {
            PriorityClass::High => 1.0,
            PriorityClass::Normal => 1.0,
            PriorityClass::Low => 0.5,
        }
    }

    pub fn as_str(self) -> &'static str {
        match self {
            PriorityClass::High => "high",
            PriorityClass::Normal => "normal",
            PriorityClass::Low => "low",
        }
    }
}

impl fmt::Display for PriorityClass {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.as_str())
    }
}

impl FromStr for PriorityClass {
    type Err = anyhow::Error;

    fn from_str(s: &str) -> Result<Self, Self::Err> {
        match s.trim() {
            "high" => Ok(PriorityClass::High),
            "normal" => Ok(PriorityClass::Normal),
            "low" => Ok(PriorityClass::Low),
            other => anyhow::bail!(
                "unknown priority class {other:?} (high|normal|low)"
            ),
        }
    }
}

/// The per-request lifecycle context: everything a request carries
/// besides *what* to compute (network + image count live on
/// [`InferenceRequest`], whose logical network name also names the
/// precision twin — `mnist` vs `mnist.q`).
#[derive(Debug, Clone, Copy)]
pub struct RequestCtx {
    /// Arrival the request is *charged from* — the workload layer
    /// stamps the scheduled arrival, so generator lag counts against
    /// the system (coordinated-omission correction by construction).
    pub arrival: Instant,
    /// Absolute deadline; `None` = best-effort (no attainment row).
    pub deadline: Option<Instant>,
    pub class: PriorityClass,
    /// Latent seed (deterministic generation for reproducible tests).
    pub seed: u64,
    /// Lifecycle stage stamps the coordinator fills in as the request
    /// travels (intake → … → reply) — fixed-size so the context stays
    /// `Copy`.  See `telemetry::trace`.
    pub stamps: StageStamps,
}

impl RequestCtx {
    /// Best-effort context arriving now — the ad-hoc `serve` path.
    pub fn new(seed: u64) -> Self {
        RequestCtx {
            arrival: Instant::now(),
            deadline: None,
            class: PriorityClass::Normal,
            seed,
            stamps: StageStamps::default(),
        }
    }

    pub fn with_deadline(mut self, deadline: Instant) -> Self {
        self.deadline = Some(deadline);
        self
    }

    pub fn with_class(mut self, class: PriorityClass) -> Self {
        self.class = class;
        self
    }

    /// Deadline the scheduler orders by: the real one, or the batching
    /// horizon for best-effort requests (so EDF degrades to FIFO when
    /// nobody carries a deadline — arrivals are monotone).
    pub fn effective_deadline(&self, max_wait: Duration) -> Instant {
        self.deadline.unwrap_or(self.arrival + max_wait)
    }

    /// Seconds left until the deadline at `now` (negative = already
    /// past); `None` for best-effort requests.
    pub fn budget_s(&self, now: Instant) -> Option<f64> {
        self.deadline.map(|d| {
            if d >= now {
                d.duration_since(now).as_secs_f64()
            } else {
                -now.duration_since(d).as_secs_f64()
            }
        })
    }
}

/// One client request: "generate `n_images` samples from `network`".
#[derive(Debug, Clone)]
pub struct InferenceRequest {
    pub id: RequestId,
    pub network: String,
    pub n_images: usize,
    /// Lifecycle context (arrival, deadline, class, latent seed).
    pub ctx: RequestCtx,
}

impl InferenceRequest {
    /// Best-effort request arriving now (the pre-deadline call shape,
    /// kept for the `serve` path and tests).
    pub fn new(id: RequestId, network: &str, n_images: usize, seed: u64) -> Self {
        Self::with_ctx(id, network, n_images, RequestCtx::new(seed))
    }

    pub fn with_ctx(
        id: RequestId,
        network: &str,
        n_images: usize,
        ctx: RequestCtx,
    ) -> Self {
        InferenceRequest {
            id,
            network: network.to_string(),
            n_images,
            ctx,
        }
    }
}

/// Completed request with its generated images and serving telemetry.
#[derive(Debug)]
pub struct InferenceResponse {
    pub id: RequestId,
    /// `[n_images, C, H, W]` in [-1, 1] — a zero-copy window into the
    /// serving batch's image buffer (requests batched together share
    /// one allocation; see [`ImageBlock`]).
    pub images: ImageBlock,
    /// End-to-end latency (charged arrival → response), seconds.
    pub latency_s: f64,
    /// Wall time inside the numeric substrate, seconds.
    pub execute_s: f64,
    /// Batch bucket this request was served in.
    pub batch_size: usize,
    /// Lane/backend that served the batch (e.g. `fpga0`).
    pub backend: String,
    /// This request's share of the serving device's (simulated or
    /// measured) batch latency, seconds.
    pub device_time_s: f64,
    /// This request's share of the serving device's batch energy, J.
    pub energy_j: f64,
    /// Pool-global execution sequence of the serving batch — makes the
    /// per-network ordering guarantee observable (and testable).
    pub exec_seq: u64,
    /// Priority class the request was served under.
    pub class: PriorityClass,
    /// Edge-charged completion time: wall queueing (charged arrival →
    /// execution start) plus the *device* batch latency — what the
    /// request would have cost on the modeled edge device, with the
    /// host numeric substrate (the simulator stand-in) excluded.
    pub charged_s: f64,
    /// Deadline verdict on the edge-charged completion (`None` =
    /// best-effort request).
    pub deadline_met: Option<bool>,
    /// The completed lifecycle stamp set (every boundary filled in by
    /// the time a response exists) — the span data the flight recorder
    /// drained, returned so callers can reconcile stage spans against
    /// `latency_s` without digging through telemetry snapshots.
    pub stamps: StageStamps,
    /// Simulated edge-FPGA latency for the same work (annotation,
    /// independent of which backend actually served it).
    pub fpga_time_s: f64,
    /// Simulated edge-GPU latency for the same work at boost clock
    /// (annotation, independent of the serving backend).
    pub gpu_time_s: f64,
}

/// How a submitted request finally resolved — the typed, in-band form
/// of the request lifecycle's four exits.  Before this enum existed a
/// shed, a rejection and a backend failure all manifested to the client
/// as the same dropped reply channel; the loadtest had to reconcile its
/// error count against the coordinator's counters after the fact, and a
/// fleet front tier could not tell "spill me elsewhere" (shed/rejected)
/// from "infrastructure trouble" (lost).
#[derive(Debug)]
pub enum RequestOutcome {
    /// Completed with a response (possibly past its deadline — see
    /// [`InferenceResponse::deadline_met`]).  Boxed: the response
    /// carries an image tensor and is much larger than the other arms.
    Served(Box<InferenceResponse>),
    /// Shed at intake: the deadline was already infeasible given queue
    /// depth × predicted cost (shed-early instead of serve-late).  The
    /// context comes back with the denial so a fleet front tier can
    /// resubmit it elsewhere with its arrival, deadline *and* intake
    /// stamps intact — the spill hop stays on the request's timeline.
    Shed { ctx: RequestCtx },
    /// Turned away by overload admission control (the deferred queue
    /// outgrew the request's class budget).  Carries the context back,
    /// like [`Shed`](RequestOutcome::Shed).
    Rejected { ctx: RequestCtx },
    /// The reply channel dropped without a verdict — backend execution
    /// failure, unservable network, or coordinator shutdown.
    /// Infrastructure loss, not load shedding.
    Lost,
}

impl RequestOutcome {
    /// Convert to the legacy `Result` shape ([`Served`] = `Ok`, every
    /// denial = a descriptive error).
    ///
    /// [`Served`]: RequestOutcome::Served
    pub fn into_response(self) -> anyhow::Result<InferenceResponse> {
        match self {
            RequestOutcome::Served(resp) => Ok(*resp),
            RequestOutcome::Shed { .. } => Err(anyhow::anyhow!(
                "request shed at intake (deadline infeasible)"
            )),
            RequestOutcome::Rejected { .. } => Err(anyhow::anyhow!(
                "request rejected (overload admission control)"
            )),
            RequestOutcome::Lost => Err(anyhow::anyhow!(
                "request dropped by coordinator"
            )),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn request_records_arrival_time() {
        let r = InferenceRequest::new(1, "mnist", 4, 42);
        assert_eq!(r.network, "mnist");
        assert_eq!(r.ctx.seed, 42);
        assert_eq!(r.ctx.class, PriorityClass::Normal);
        assert!(r.ctx.deadline.is_none());
        assert!(r.ctx.arrival.elapsed().as_secs_f64() < 1.0);
    }

    #[test]
    fn effective_deadline_falls_back_to_the_batching_horizon() {
        let ctx = RequestCtx::new(1);
        let horizon = Duration::from_millis(5);
        assert_eq!(ctx.effective_deadline(horizon), ctx.arrival + horizon);
        let d = ctx.arrival + Duration::from_millis(50);
        let with = ctx.with_deadline(d);
        assert_eq!(with.effective_deadline(horizon), d);
    }

    #[test]
    fn budget_signs_around_the_deadline() {
        let ctx = RequestCtx::new(0);
        assert!(ctx.budget_s(Instant::now()).is_none(), "best-effort");
        let d = ctx.arrival + Duration::from_millis(10);
        let ctx = ctx.with_deadline(d);
        let before = ctx.budget_s(ctx.arrival).unwrap();
        assert!((before - 0.010).abs() < 1e-9);
        let after = ctx.budget_s(d + Duration::from_millis(3)).unwrap();
        assert!((after + 0.003).abs() < 1e-9, "past deadline goes negative");
    }

    #[test]
    fn denial_outcomes_map_to_descriptive_errors() {
        let ctx = RequestCtx::new(0);
        for (outcome, needle) in [
            (RequestOutcome::Shed { ctx }, "shed"),
            (RequestOutcome::Rejected { ctx }, "rejected"),
            (RequestOutcome::Lost, "dropped"),
        ] {
            let err = outcome.into_response().unwrap_err().to_string();
            assert!(err.contains(needle), "{err}");
        }
    }

    #[test]
    fn class_parse_display_roundtrip_and_ranks() {
        for c in [PriorityClass::High, PriorityClass::Normal, PriorityClass::Low]
        {
            assert_eq!(c.as_str().parse::<PriorityClass>().unwrap(), c);
        }
        assert!("urgent".parse::<PriorityClass>().is_err());
        assert!(PriorityClass::High.rank() < PriorityClass::Normal.rank());
        assert!(PriorityClass::Normal.rank() < PriorityClass::Low.rank());
        assert_eq!(PriorityClass::default(), PriorityClass::Normal);
        assert!(
            PriorityClass::Low.shed_fraction()
                < PriorityClass::Normal.shed_fraction(),
            "the low class gives up its admission budget first"
        );
    }
}
