//! Executor lanes — one thread per pool backend, each owning one live
//! [`Backend`](crate::backend::Backend) instance plus the network
//! metadata it serves.  A lane is a **FIFO queue**: batches execute in
//! arrival order, which is the ordering half of the scheduler's
//! per-network guarantee (the routing half — a network never jumps to
//! another lane while it has work in flight — lives in
//! [`super::scheduler`]).
//!
//! The lane resolves waiters and records metrics itself, then decrements
//! its depth/outstanding counters **after** the replies are sent — the
//! scheduler treats `outstanding == 0` as "all prior batches fully
//! resolved", which is what makes lane re-pinning safe.

use super::batcher::Batch;
use super::metrics::MetricsRegistry;
use super::request::{InferenceResponse, RequestOutcome};
use crate::artifacts::ArtifactDir;
use crate::backend::{
    dense_network_sim, instantiate, Backend, CostModel, NetSpec,
};
use crate::config::{
    network_by_name, DeviceKind, NetworkCfg, Precision, JETSON_TX1,
};
use crate::gpu::expected_gpu_network_time_at;
use crate::telemetry::{RunClock, SpanRecord};
use crate::tensor::{ImageBlock, Tensor};
use crate::util::{Rng, WorkerPool};
use anyhow::{Context, Result};
use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::time::Instant;

/// Commands a lane accepts from the scheduler.
pub(crate) enum LaneCmd {
    Execute {
        batch: Batch,
        /// Reply channel per request id; dropped on failure so callers
        /// observe a [`RequestOutcome::Lost`] instead of hanging.
        replies: Vec<(u64, mpsc::Sender<RequestOutcome>)>,
    },
    Shutdown,
}

/// Static description of the lane to spawn.
pub(crate) struct LaneSpec {
    pub name: String,
    pub kind: DeviceKind,
    /// Logical networks routable to this lane, with served precisions.
    pub networks: Vec<(String, Precision)>,
    /// Pool width (lanes split the host compute budget evenly).
    pub n_lanes: usize,
    pub artifacts_dir: std::path::PathBuf,
    /// Seed for the backend's measurement-noise stream (per lane; the
    /// loadtest varies it per trial so repeated trials are independent
    /// measurements, not replays).
    pub noise_seed: u64,
}

/// Counters shared with the scheduler.
pub(crate) struct LaneShared {
    pub metrics: Arc<Mutex<MetricsRegistry>>,
    /// Not-yet-executed batches queued on this lane.
    pub depth: Arc<AtomicUsize>,
    /// Per logical network: batches dispatched but not yet resolved
    /// (across all lanes — the map is pool-global).
    pub outstanding: HashMap<String, Arc<AtomicUsize>>,
    /// Pool-global execution sequence (stamps responses so ordering is
    /// observable/testable).
    pub exec_seq: Arc<AtomicU64>,
    /// Per-network cost models the scheduler routes on — written by
    /// this lane at startup and re-probed on DVFS throttle transitions
    /// (see [`refresh_costs`]).
    pub costs: Arc<Mutex<HashMap<String, CostModel>>>,
    /// The coordinator's run clock — the lane stamps execute start/end
    /// and reply boundaries against it (see `telemetry::trace`).
    pub clock: RunClock,
}

/// Re-probe every loaded network's cost model into the shared map —
/// called at lane startup and again whenever the device's throttle
/// state flips, so the scheduler's routing tracks the clock the device
/// actually runs at instead of the boost-clock startup probe.
pub(crate) fn refresh_costs(
    backend: &dyn Backend,
    networks: impl Iterator<Item = impl AsRef<str>>,
    costs: &Mutex<HashMap<String, CostModel>>,
) {
    let probed: Vec<(String, CostModel)> = networks
        .filter_map(|n| {
            backend
                .cost_model(n.as_ref())
                .map(|c| (n.as_ref().to_string(), c))
        })
        .collect();
    costs.lock().unwrap().extend(probed);
}

/// Per-network metadata the lane keeps outside the backend: the config
/// (latent dims, output geometry) and the per-image FPGA edge
/// annotation every response carries regardless of which backend served
/// it.  (The FPGA annotation is per-image linear — the accelerator
/// streams one image at a time — while the GPU annotation amortizes
/// launch overhead with batch size, so it is computed per batch at
/// execution time, not precomputed per image.)
struct NetMeta {
    cfg: NetworkCfg,
    fpga_s: f64,
}

/// Build the [`NetSpec`] for one logical network from the artifact set.
pub(crate) fn load_net_spec(
    artifacts: &ArtifactDir,
    name: &str,
    precision: Precision,
) -> Result<NetSpec> {
    // `.q8` and `.q` twins both serve from the base f32 artifact set
    let base = name
        .strip_suffix(".q8")
        .or_else(|| name.strip_suffix(".q"))
        .unwrap_or(name)
        .to_string();
    let manifest_net = artifacts.network(&base)?;
    let cfg = artifacts.network_cfg(&base)?;
    // sanity: manifest must agree with the built-in architecture
    let builtin = network_by_name(&base)?;
    anyhow::ensure!(
        cfg.layers == builtin.layers,
        "manifest/{base} diverges from built-in config"
    );
    let weights = artifacts.load_weights(&base)?;
    Ok(NetSpec {
        name: name.to_string(),
        base,
        buckets: manifest_net.batch_sizes.clone(),
        precision,
        weights,
        cfg,
    })
}

fn annotate(spec: &NetSpec) -> NetMeta {
    let sim = dense_network_sim(&spec.cfg, spec.precision);
    NetMeta {
        fpga_s: sim.total_time_s,
        cfg: spec.cfg.clone(),
    }
}

/// The lane thread body: load, report readiness + costs, serve FIFO.
pub(crate) fn lane_thread(
    spec: LaneSpec,
    rx: mpsc::Receiver<LaneCmd>,
    ready: mpsc::Sender<Result<()>>,
    shared: LaneShared,
) {
    let setup = (|| -> Result<(Box<dyn Backend>, HashMap<String, NetMeta>)> {
        let artifacts = ArtifactDir::open(&spec.artifacts_dir)?;
        // split the host's compute budget across the pool so lanes
        // running concurrently don't oversubscribe the CPU (the width
        // honours the EDGEDCNN_WORKERS override)
        let host_workers = WorkerPool::with_default_parallelism().workers();
        let pool = WorkerPool::new((host_workers / spec.n_lanes).max(1));
        let mut backend =
            instantiate(spec.kind, spec.name.clone(), pool, spec.noise_seed)?;
        let mut metas = HashMap::new();
        for (name, precision) in &spec.networks {
            let net_spec = load_net_spec(&artifacts, name, *precision)
                .with_context(|| format!("loading {name} on {}", spec.name))?;
            backend.load(&net_spec, &artifacts)?;
            metas.insert(name.clone(), annotate(&net_spec));
        }
        Ok((backend, metas))
    })();

    let (mut backend, metas) = match setup {
        Ok((backend, metas)) => {
            refresh_costs(backend.as_ref(), metas.keys(), &shared.costs);
            let _ = ready.send(Ok(()));
            (backend, metas)
        }
        Err(e) => {
            let _ = ready.send(Err(e));
            return;
        }
    };

    // DVFS-aware routing: remember the device's throttle state and
    // re-probe the cost models whenever it flips, in either direction
    // (the startup probe ran at boost clock; sustained load must not
    // keep routing on boost-clock costs)
    let mut was_throttled = false;
    while let Ok(cmd) = rx.recv() {
        match cmd {
            LaneCmd::Shutdown => break,
            LaneCmd::Execute { batch, replies } => {
                let network = batch.network.clone();
                match execute_batch(backend.as_mut(), &metas, &shared, batch) {
                    Ok((responses, throttled)) => {
                        resolve(replies, responses);
                        if throttled != was_throttled {
                            was_throttled = throttled;
                            refresh_costs(
                                backend.as_ref(),
                                metas.keys(),
                                &shared.costs,
                            );
                            shared
                                .metrics
                                .lock()
                                .unwrap()
                                .record_cost_refresh(backend.name());
                        }
                    }
                    Err(e) => {
                        eprintln!(
                            "backend {} execution failed: {e:#}",
                            backend.name()
                        );
                        // dropping `replies` errors the callers
                    }
                }
                // depth/outstanding drop only after the replies went
                // out (see module docs: re-pinning safety)
                shared.depth.fetch_sub(1, Ordering::AcqRel);
                if let Some(o) = shared.outstanding.get(&network) {
                    o.fetch_sub(1, Ordering::AcqRel);
                }
            }
        }
    }
}

fn resolve(
    replies: Vec<(u64, mpsc::Sender<RequestOutcome>)>,
    responses: Vec<InferenceResponse>,
) {
    let mut reply_by_id: HashMap<u64, mpsc::Sender<RequestOutcome>> =
        replies.into_iter().collect();
    for resp in responses {
        if let Some(tx) = reply_by_id.remove(&resp.id) {
            let _ = tx.send(RequestOutcome::Served(Box::new(resp)));
        }
    }
}

/// Execute one batch on the lane's backend and split the outcome back
/// into per-request responses (recording metrics on the way).  Also
/// returns whether the device reported a throttled clock, so the lane
/// loop can re-probe cost models on transitions.
fn execute_batch(
    backend: &mut dyn Backend,
    metas: &HashMap<String, NetMeta>,
    shared: &LaneShared,
    mut batch: Batch,
) -> Result<(Vec<InferenceResponse>, bool)> {
    let meta = metas.get(&batch.network).ok_or_else(|| {
        anyhow::anyhow!("network {:?} not loaded", batch.network)
    })?;

    // execution start: the edge-charged completion time of every
    // request in the batch is its wall queueing up to this point plus
    // the *device* batch latency — the host numeric substrate below is
    // the simulator stand-in and is deliberately excluded from the
    // deadline verdict (see DESIGN.md §Deadline scheduling)
    let started = Instant::now();
    for req in &mut batch.requests {
        req.ctx.stamps.on_exec_start(&shared.clock, started);
    }

    // deterministic latents: one RNG per request, in order — identical
    // on every backend, which is what makes routing invisible to
    // clients (bit-identical f32 outputs)
    let mut latents: Vec<f32> =
        Vec::with_capacity(batch.n_images * meta.cfg.z_dim);
    for req in &batch.requests {
        let mut rng = Rng::seed_from_u64(req.ctx.seed);
        for _ in 0..req.n_images * meta.cfg.z_dim {
            latents.push(rng.normal_f32());
        }
    }
    let z = Tensor::new(vec![batch.n_images, meta.cfg.z_dim], latents)?;

    let outcome = backend.execute(&batch.network, &z)?;
    let exec_ended = Instant::now();
    for req in &mut batch.requests {
        req.ctx.stamps.on_exec_end(&shared.clock, exec_ended);
    }
    let seq = shared.exec_seq.fetch_add(1, Ordering::AcqRel);
    // GPU edge annotation at the *actual* batch size (launch overhead
    // amortizes with batching), boost clock, pro-rated per request
    let gpu_batch_s = expected_gpu_network_time_at(
        &meta.cfg,
        &JETSON_TX1,
        JETSON_TX1.boost_clock_hz,
        batch.n_images,
    );

    // one edge-charged verdict per request, shared by the metrics
    // accounting and the response fields (a single copy of the formula
    // keeps ServingReport attainment and per-response `deadline_met`
    // from ever diverging)
    let verdicts: Vec<(f64, Option<bool>)> = batch
        .requests
        .iter()
        .map(|req| {
            let wait_s = started
                .saturating_duration_since(req.ctx.arrival)
                .as_secs_f64();
            let charged_s = wait_s + outcome.device_time_s;
            let met = req.ctx.deadline.map(|d| {
                let budget_s = d
                    .saturating_duration_since(req.ctx.arrival)
                    .as_secs_f64();
                charged_s <= budget_s
            });
            (charged_s, met)
        })
        .collect();

    {
        let mut m = shared.metrics.lock().unwrap();
        m.record_batch(outcome.execute_s, batch.n_images, outcome.ops);
        // hot-path arena high-water as observed by this lane thread
        // (covers the serial path and the worker pool's inline job);
        // pool-worker arenas are scoped per dispatch and die before
        // this read, so the column is the lane-thread view by design
        m.record_scratch_hwm(crate::util::scratch_hwm_bytes());
        m.record_energy(outcome.energy_j);
        m.record_backend_batch(
            backend.name(),
            &batch.network,
            batch.n_images,
            outcome.ops,
            outcome.device_time_s,
            outcome.energy_j,
        );
        for (req, (_, met)) in batch.requests.iter().zip(&verdicts) {
            let latency_s = req.ctx.arrival.elapsed().as_secs_f64();
            m.record_request(latency_s, req.n_images);
            m.record_backend_request(backend.name(), latency_s);
            if let Some(met) = met {
                m.record_backend_deadline(backend.name(), req.ctx.class, *met);
            }
        }
    }

    // Split images back to requests — zero-copy: the whole batch
    // buffer moves into one shared [`ImageBlock`] and every response
    // gets an O(1) row window of it.  A served image is generated once
    // by the backend and never memcpy'd again on its way to the client.
    let throttled = outcome.state.throttled;
    let batch_images = ImageBlock::from_tensor(outcome.images);
    debug_assert_eq!(
        batch_images.shape(),
        &[
            batch.n_images,
            meta.cfg.image_channels,
            meta.cfg.image_size,
            meta.cfg.image_size,
        ],
        "backend returned an unexpected batch geometry"
    );
    let n_batch = batch.n_images as f64;
    let mut responses = Vec::with_capacity(batch.requests.len());
    let mut row = 0usize;
    let reply_at = Instant::now();
    for (req, (charged_s, deadline_met)) in
        batch.requests.iter_mut().zip(verdicts)
    {
        req.ctx.stamps.on_reply(&shared.clock, reply_at);
        let n = req.n_images;
        let images = batch_images.slice_images(row, n);
        row += n;
        let share = n as f64 / n_batch;
        responses.push(InferenceResponse {
            id: req.id,
            images,
            latency_s: req.ctx.arrival.elapsed().as_secs_f64(),
            execute_s: outcome.execute_s,
            batch_size: batch.n_images,
            backend: backend.name().to_string(),
            device_time_s: outcome.device_time_s * share,
            energy_j: outcome.energy_j * share,
            exec_seq: seq,
            class: req.ctx.class,
            charged_s,
            deadline_met,
            stamps: req.ctx.stamps,
            fpga_time_s: meta.fpga_s * n as f64,
            gpu_time_s: gpu_batch_s * share,
        });
    }

    // flight recorder drain: the lifecycle is complete now — fold the
    // stage spans into the per-(backend, class) breakdown and push the
    // deterministically head-sampled span sets into this lane's ring
    {
        let mut m = shared.metrics.lock().unwrap();
        for req in &batch.requests {
            let Some(spans) = req.ctx.stamps.stage_spans() else {
                continue;
            };
            m.record_stages(backend.name(), req.ctx.class, &spans);
            if req.ctx.stamps.sampled {
                m.record_span(
                    backend.name(),
                    SpanRecord {
                        id: req.id,
                        seed: req.ctx.seed,
                        class: req.ctx.class,
                        n_images: req.n_images,
                        stamps: req.ctx.stamps,
                    },
                );
            }
        }
    }
    Ok((responses, throttled))
}
