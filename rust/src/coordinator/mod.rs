//! L3 edge-inference coordinator — the serving system wrapped around the
//! accelerators: request intake, dynamic batching into the AOT-exported
//! batch buckets, and a **heterogeneous device-backend pool** — one FIFO
//! executor lane per configured device ([`crate::backend`]: the PYNQ-Z2
//! simulator datapath, the Jetson TX1 thermal model, the host CPU
//! numeric path) with capability- and cost-aware routing between them.
//! The paper's FPGA-vs-GPU comparison is therefore a *live scheduling
//! decision*: each batch goes to the cheapest idle capable device, and
//! the per-backend columns of [`ServingReport`] show where the work
//! landed and at what latency/energy.
//!
//! Module split:
//! * [`registry`](BackendRegistry) — logical networks (incl. `.q`
//!   quantized twins) → capable lanes;
//! * `scheduler` — the leader thread: batching, routing (per-network
//!   ordering via lane pinning + per-lane FIFO), backpressure and
//!   admission control;
//! * `executor` — the lane threads owning the live backends;
//! * `server` — configuration, startup wiring, and the client API.
//!
//! Threading model: PJRT handles are not `Sync`, so each lane owns its
//! runtime/backend; the leader does intake/batching/routing and talks to
//! lanes over channels — the same leader/worker split a vLLM-style
//! router uses, on std threads (the offline build ships no async
//! runtime).

mod batcher;
mod executor;
mod metrics;
mod power;
mod registry;
mod request;
mod routing;
mod scheduler;
mod server;

pub use batcher::{Batch, BatcherConfig, DynamicBatcher};
pub use metrics::{
    BackendReport, LaneQueueReport, LatencyReport, MetricsRegistry,
    ServingReport,
};
pub use power::PowerMeter;
pub use registry::{BackendRegistry, LaneInfo};
pub use request::{InferenceRequest, InferenceResponse, RequestId};
pub use server::{
    Coordinator, CoordinatorConfig, ResponseHandle, WorkloadSpec,
};
