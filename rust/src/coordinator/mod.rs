//! L3 edge-inference coordinator — the serving system wrapped around the
//! accelerators: request intake, dynamic batching into the AOT-exported
//! batch buckets, and a **heterogeneous device-backend pool** — one FIFO
//! executor lane per configured device ([`crate::backend`]: the PYNQ-Z2
//! simulator datapath, the Jetson TX1 thermal model, the host CPU
//! numeric path) with capability- and cost-aware routing between them.
//! The paper's FPGA-vs-GPU comparison is therefore a *live scheduling
//! decision*: each batch goes to the cheapest idle capable device, and
//! the per-backend columns of [`ServingReport`] show where the work
//! landed and at what latency/energy.
//!
//! Every request carries a [`RequestCtx`] (arrival, absolute deadline,
//! priority class, latent seed) from intake to verdict: the batcher is
//! EDF-ordered and cuts on deadline *slack*, intake sheds requests
//! whose deadline no lane can meet (shed-early instead of serve-late),
//! and the report accounts deadline attainment per (backend, class) —
//! see DESIGN.md §Deadline scheduling.
//!
//! Module split:
//! * [`registry`](BackendRegistry) — logical networks (incl. `.q`
//!   quantized twins) → capable lanes;
//! * `scheduler` — the leader thread: deadline-aware intake (admission
//!   + infeasibility shedding), EDF batching, routing (per-network
//!   ordering via lane pinning + per-lane FIFO), backpressure;
//! * `executor` — the lane threads owning the live backends;
//! * `server` — configuration, startup wiring, and the client API.
//!
//! Threading model: PJRT handles are not `Sync`, so each lane owns its
//! runtime/backend; the leader does intake/batching/routing and talks to
//! lanes over channels — the same leader/worker split a vLLM-style
//! router uses, on std threads (the offline build ships no async
//! runtime).

mod batcher;
mod executor;
mod metrics;
mod power;
mod registry;
mod request;
mod routing;
mod scheduler;
mod server;

pub use batcher::{Batch, BatcherConfig, DynamicBatcher};
pub use metrics::{
    BackendReport, ClassAttainment, DriftWindow, LaneQueueReport,
    LatencyReport, MetricsRegistry, ServingReport, StageBreakdown, StageRow,
};
pub use power::PowerMeter;
pub use registry::{BackendRegistry, LaneInfo};
pub use request::{
    InferenceRequest, InferenceResponse, PriorityClass, RequestCtx, RequestId,
    RequestOutcome,
};
pub use server::{
    Coordinator, CoordinatorClient, CoordinatorConfig, RequestBuilder,
    ResponseHandle, WorkloadSpec,
};
