//! L3 edge-inference coordinator — the serving system wrapped around the
//! accelerator: request intake, dynamic batching into the AOT-exported
//! batch buckets, a device-executor thread owning the PJRT runtime (and
//! the FPGA/GPU timing simulators for edge-device annotations), metrics,
//! and a sampled power meter.  With `CoordinatorConfig::quant` set,
//! every network also serves a fixed-point twin under `<name>.q`
//! (calibrated at startup, executed through the quantized reverse-loop
//! substrate) side by side with the f32 path; `shard_batches` splits
//! multi-request batches across the executor pool.
//!
//! Threading model: PJRT handles are not `Sync`, so one **device thread**
//! owns the [`crate::runtime::Runtime`] and all compiled executables; a
//! **leader thread** does intake/batching/dispatch and talks to it over
//! channels — the same leader/worker split a vLLM-style router uses.

mod batcher;
mod metrics;
mod power;
mod request;
mod server;

pub use batcher::{Batch, BatcherConfig, DynamicBatcher};
pub use metrics::{MetricsRegistry, ServingReport};
pub use power::PowerMeter;
pub use request::{InferenceRequest, InferenceResponse, RequestId};
pub use server::{
    Coordinator, CoordinatorConfig, ResponseHandle, WorkloadSpec,
};
