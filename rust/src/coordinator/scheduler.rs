//! The scheduler (leader thread): deadline-aware request intake →
//! EDF dynamic batching → **capability- and cost-aware routing** over
//! the heterogeneous lane pool.
//!
//! Routing invariants (see DESIGN.md §Backend layer and §Deadline
//! scheduling):
//!
//! 1. **Capability** — a batch only ever goes to a lane whose backend
//!    supports the network's served precision (the [`BackendRegistry`]
//!    is consulted, never bypassed).
//! 2. **Cost** — among *idle* capable lanes the cheapest (per the lane's
//!    reported [`CostModel`] at this batch size) wins; when nobody is
//!    idle, the shallowest queue wins (cost breaks ties).
//! 3. **Ordering** — a network with batches in flight is *pinned* to
//!    their lane: later batches either join that FIFO lane or defer.
//!    Only when the network is quiescent (`outstanding == 0`, i.e. all
//!    replies sent) may the scheduler re-route it.  EDF reorders
//!    *within* the batcher queue (by deadline) and *between* networks
//!    (urgent networks retry first); it never reorders one network's
//!    cut batches — deferred batches of a network retry in admission
//!    order, so per-network responses still resolve in cut order.
//! 4. **Backpressure/admission** — a lane at `max_queue_depth` accepts
//!    no more batches; when every capable lane is saturated the batch
//!    defers (retried in EDF slack order as lanes drain).  Intake sheds
//!    early on two conditions: (a) *overload* — the deferred queue has
//!    outgrown the request's class budget (`admit_max_deferred ×
//!    class.shed_fraction()`, so the low class yields first), and
//!    (b) *infeasibility* — the request carries a deadline no capable
//!    lane can meet given its queue depth × predicted cost
//!    ([`CostModel::slack_s`]); serving it would only produce a
//!    served-late response, so it is shed at arrival instead.
//!
//! [`CostModel`]: crate::backend::CostModel

use super::batcher::{Batch, BatcherConfig, DynamicBatcher};
use super::executor::LaneCmd;
use super::metrics::MetricsRegistry;
use super::registry::BackendRegistry;
use super::request::{InferenceRequest, RequestOutcome};
use super::routing::{choose_lane, retry_order, DeferredView, LaneView, Route};
use crate::backend::CostModel;
use crate::config::BackendCfg;
use crate::telemetry::RunClock;
use std::collections::{HashMap, HashSet};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::time::{Duration, Instant};

pub(crate) enum LeaderCmd {
    Submit(InferenceRequest, mpsc::Sender<RequestOutcome>),
    Shutdown,
}

/// The scheduler's handle on one executor lane.
pub(crate) struct LaneHandle {
    /// Lane name (`fpga0`, …) — keys the per-lane telemetry.
    pub name: String,
    pub tx: mpsc::Sender<LaneCmd>,
    pub depth: Arc<AtomicUsize>,
    /// Per-network cost models, shared with the lane thread: filled at
    /// startup and *re-probed* by the lane when its device crosses a
    /// DVFS throttle threshold, so routing tracks the clock the device
    /// actually runs at (not the boost-clock probe forever).
    pub costs: Arc<Mutex<HashMap<String, CostModel>>>,
}

/// One deferred batch plus its admission sequence (the per-network
/// FIFO key the EDF retry order preserves).
struct Deferred {
    batch: Batch,
    seq: u64,
}

/// Everything the leader thread owns.
pub(crate) struct Scheduler {
    batcher: DynamicBatcher,
    cfg: BackendCfg,
    shard_batches: bool,
    lanes: Vec<LaneHandle>,
    registry: BackendRegistry,
    /// Per-network in-flight batch counters (decremented lane-side
    /// after replies resolve).
    outstanding: HashMap<String, Arc<AtomicUsize>>,
    /// Current lane pin per network (leader-private; only meaningful
    /// while the network's outstanding counter is nonzero).
    pins: HashMap<String, usize>,
    /// Batches waiting for lane capacity; retried in EDF slack order
    /// (per-network admission order preserved).
    deferred: Vec<Deferred>,
    defer_seq: u64,
    waiters: HashMap<u64, mpsc::Sender<RequestOutcome>>,
    metrics: Arc<Mutex<MetricsRegistry>>,
    /// The run clock every lifecycle stamp is taken against (site
    /// epoch + seeded skew; see telemetry::trace).
    clock: RunClock,
}

impl Scheduler {
    fn lane_views(&self, network: &str, n_images: usize) -> Vec<LaneView> {
        let capable = self.registry.capable(network);
        let infos = self.registry.lanes();
        self.lanes
            .iter()
            .enumerate()
            .map(|(i, l)| LaneView {
                capable: capable.contains(&i)
                    && infos[i].caps.admits(n_images),
                depth: l.depth.load(Ordering::Acquire),
                cost_s: l
                    .costs
                    .lock()
                    .unwrap()
                    .get(network)
                    .map(|c| c.cost_s(n_images))
                    .unwrap_or(f64::INFINITY),
            })
            .collect()
    }

    fn pinned(&self, network: &str) -> Option<usize> {
        let pin = *self.pins.get(network)?;
        let live = self
            .outstanding
            .get(network)
            .map(|o| o.load(Ordering::Acquire) > 0)
            .unwrap_or(false);
        live.then_some(pin)
    }

    /// Cheapest capable lane's cost model for a network — the live
    /// "predicted cost" the batcher's slack cutting and the deferred
    /// queue's EDF ordering run on.
    fn cheapest_cost(&self, network: &str, n_images: usize) -> Option<CostModel> {
        let mut best: Option<(f64, CostModel)> = None;
        for &i in self.registry.capable(network) {
            let Some(cm) = self.lanes[i]
                .costs
                .lock()
                .unwrap()
                .get(network)
                .copied()
            else {
                continue;
            };
            let c = cm.cost_s(n_images);
            if best.map(|(b, _)| c < b).unwrap_or(true) {
                best = Some((c, cm));
            }
        }
        best.map(|(_, cm)| cm)
    }

    /// Shed-early feasibility check (invariant 4b): `true` when the
    /// request carries a deadline that *no* capable lane can meet given
    /// its current queue depth and predicted cost.  Requests without a
    /// deadline — and networks whose lanes have not reported a cost
    /// model yet — are never shed here.
    fn intake_infeasible(&self, req: &InferenceRequest, now: Instant) -> bool {
        let Some(deadline) = req.ctx.deadline else {
            return false;
        };
        if deadline <= now {
            return true; // already past: serving can only be late
        }
        let budget_s = deadline.duration_since(now).as_secs_f64();
        let infos = self.registry.lanes();
        let mut any_model = false;
        for &i in self.registry.capable(&req.network) {
            if !infos[i].caps.admits(req.n_images) {
                continue;
            }
            let Some(cm) = self.lanes[i]
                .costs
                .lock()
                .unwrap()
                .get(&req.network)
                .copied()
            else {
                continue;
            };
            any_model = true;
            let depth = self.lanes[i].depth.load(Ordering::Acquire);
            if cm.slack_s(budget_s, depth, req.n_images) >= 0.0 {
                return false; // some lane still makes the deadline
            }
        }
        any_model
    }

    fn send(&mut self, lane: usize, mut batch: Batch) {
        let now = Instant::now();
        let mut replies = Vec::with_capacity(batch.requests.len());
        for r in &mut batch.requests {
            r.ctx.stamps.on_dispatch(&self.clock, now);
            if let Some(tx) = self.waiters.remove(&r.id) {
                replies.push((r.id, tx));
            }
        }
        let network = batch.network.clone();
        if let Some(o) = self.outstanding.get(&network) {
            o.fetch_add(1, Ordering::AcqRel);
        }
        self.pins.insert(network.clone(), lane);
        let depth = self.lanes[lane].depth.fetch_add(1, Ordering::AcqRel) + 1;
        self.metrics
            .lock()
            .unwrap()
            .record_lane_dispatch(&self.lanes[lane].name, depth);
        if self.lanes[lane]
            .tx
            .send(LaneCmd::Execute { batch, replies })
            .is_err()
        {
            // lane gone: the replies just dropped, so every caller of
            // this batch observes an error instead of hanging; roll the
            // counters back so the network is not pinned to a dead lane
            self.lanes[lane].depth.fetch_sub(1, Ordering::AcqRel);
            if let Some(o) = self.outstanding.get(&network) {
                o.fetch_sub(1, Ordering::AcqRel);
            }
            eprintln!("executor lane {lane} is down; dropping a batch");
        }
    }

    /// Route one batch (invariants 1-4); the batch comes back on defer.
    fn try_dispatch(&mut self, batch: Batch) -> Result<(), Batch> {
        let batch = if self.shard_batches && batch.requests.len() >= 2 {
            match self.try_shard(batch) {
                None => return Ok(()),
                // capable pool too narrow to shard: route it whole
                Some(b) => b,
            }
        } else {
            batch
        };
        let views = self.lane_views(&batch.network, batch.n_images);
        match choose_lane(
            &views,
            self.pinned(&batch.network),
            self.cfg.max_queue_depth,
        ) {
            Route::Lane(lane) => {
                self.send(lane, batch);
                Ok(())
            }
            Route::Defer => Err(batch),
        }
    }

    /// Intra-batch parallelism: split the batch round-robin at request
    /// granularity across the *capable* lanes.  Returns the batch back
    /// when fewer than two lanes can serve it, or when any capable lane
    /// is at the depth bound — sharding must not bypass backpressure,
    /// so a congested pool falls back to whole-batch routing (which
    /// defers, keeping admission control live).
    fn try_shard(&mut self, batch: Batch) -> Option<Batch> {
        let capable: Vec<usize> =
            self.registry.capable(&batch.network).to_vec();
        if capable.len() < 2 {
            return Some(batch);
        }
        let congested = capable.iter().any(|&i| {
            self.lanes[i].depth.load(Ordering::Acquire)
                >= self.cfg.max_queue_depth
        });
        if congested {
            return Some(batch);
        }
        let n_shards = capable.len().min(batch.requests.len());
        let network = batch.network;
        let mut groups: Vec<Vec<InferenceRequest>> =
            (0..n_shards).map(|_| Vec::new()).collect();
        for (i, r) in batch.requests.into_iter().enumerate() {
            groups[i % n_shards].push(r);
        }
        for (gi, requests) in groups.into_iter().enumerate() {
            let n_images = requests.iter().map(|r| r.n_images).sum();
            let deadline =
                requests.iter().filter_map(|r| r.ctx.deadline).min();
            let shard = Batch {
                network: network.clone(),
                requests,
                n_images,
                deadline,
            };
            self.send(capable[gi % capable.len()], shard);
        }
        None
    }

    /// Park a batch on the deferred queue (metrics + admission seq).
    fn defer(&mut self, batch: Batch) {
        self.metrics.lock().unwrap().record_deferred();
        let seq = self.defer_seq;
        self.defer_seq += 1;
        self.deferred.push(Deferred { batch, seq });
    }

    /// Queue a batch behind any deferred work of the same network (or
    /// dispatch it if the coast is clear).
    fn dispatch_or_defer(&mut self, batch: Batch) {
        if self.registry.capable(&batch.network).is_empty() {
            // unknown/unservable network: error the callers now instead
            // of deferring forever (dropping the waiters does it)
            eprintln!(
                "no capable backend for network {:?}; dropping a batch",
                batch.network
            );
            for r in &batch.requests {
                self.waiters.remove(&r.id);
            }
            return;
        }
        let behind = self
            .deferred
            .iter()
            .any(|d| d.batch.network == batch.network);
        if behind {
            self.defer(batch);
            return;
        }
        if let Err(batch) = self.try_dispatch(batch) {
            self.defer(batch);
        }
    }

    /// Retry deferred batches in EDF slack order (most urgent network
    /// first, per-network admission order preserved); a network that
    /// still can't route blocks its later batches (ordering), not other
    /// networks'.
    fn drain_deferred(&mut self) {
        if self.deferred.is_empty() {
            return;
        }
        let now = Instant::now();
        // dense network indices for the pure ordering function
        let mut net_idx: HashMap<&str, usize> = HashMap::new();
        let mut views = Vec::with_capacity(self.deferred.len());
        for d in &self.deferred {
            let next = net_idx.len();
            let idx = *net_idx.entry(d.batch.network.as_str()).or_insert(next);
            let slack_s = d.batch.deadline.map(|dl| {
                let budget = if dl >= now {
                    dl.duration_since(now).as_secs_f64()
                } else {
                    -now.duration_since(dl).as_secs_f64()
                };
                let cost = self
                    .batcher
                    .cost_hint(&d.batch.network)
                    .map(|c| c.cost_s(d.batch.n_images))
                    .unwrap_or(0.0);
                budget - cost
            });
            views.push(DeferredView {
                network: idx,
                slack_s,
                seq: d.seq,
            });
        }
        let order = retry_order(&views);

        let mut blocked: HashSet<String> = HashSet::new();
        let mut slots: Vec<Option<Deferred>> =
            self.deferred.drain(..).map(Some).collect();
        let mut still: Vec<Deferred> = Vec::new();
        for i in order {
            let d = slots[i].take().expect("order indices are unique");
            if blocked.contains(&d.batch.network) {
                still.push(d);
                continue;
            }
            let seq = d.seq;
            match self.try_dispatch(d.batch) {
                Ok(()) => {}
                Err(batch) => {
                    blocked.insert(batch.network.clone());
                    still.push(Deferred { batch, seq });
                }
            }
        }
        // keep admission order within the surviving queue
        still.sort_by_key(|d| d.seq);
        self.deferred = still;
    }
}

/// Leader loop: intake (admission + shed-early) → EDF batching →
/// routing; never blocks on execution.
pub(crate) fn leader_thread(
    batcher_cfg: BatcherConfig,
    backend_cfg: BackendCfg,
    shard_batches: bool,
    rx: mpsc::Receiver<LeaderCmd>,
    lanes: Vec<LaneHandle>,
    registry: BackendRegistry,
    outstanding: HashMap<String, Arc<AtomicUsize>>,
    metrics: Arc<Mutex<MetricsRegistry>>,
    clock: RunClock,
    exec_handles: Vec<std::thread::JoinHandle<()>>,
) {
    let mut s = Scheduler {
        batcher: DynamicBatcher::with_clock(batcher_cfg, clock),
        cfg: backend_cfg,
        shard_batches,
        lanes,
        registry,
        outstanding,
        pins: HashMap::new(),
        deferred: Vec::new(),
        defer_seq: 0,
        waiters: HashMap::new(),
        metrics,
        clock,
    };
    // retry tick while batches are deferred (lane drain is observed via
    // the shared depth counters, not messages)
    let retry_tick = Duration::from_micros(200);
    let mut shutdown = false;
    'outer: loop {
        // wait for a request, the next batching cut, or — with
        // deferred work — the backpressure retry tick
        let deadline = match (s.batcher.next_deadline(), s.deferred.is_empty())
        {
            (Some(d), true) => Some(d),
            (Some(d), false) => Some(d.min(Instant::now() + retry_tick)),
            (None, false) => Some(Instant::now() + retry_tick),
            (None, true) => None,
        };
        let cmd = match deadline {
            Some(deadline) => {
                let timeout =
                    deadline.saturating_duration_since(Instant::now());
                match rx.recv_timeout(timeout) {
                    Ok(cmd) => Some(cmd),
                    Err(mpsc::RecvTimeoutError::Timeout) => None,
                    Err(mpsc::RecvTimeoutError::Disconnected) => break,
                }
            }
            None => match rx.recv() {
                Ok(cmd) => Some(cmd),
                Err(_) => break,
            },
        };
        // §Perf L3: requests arriving while the devices execute pile up
        // in the channel — drain the whole burst into the batcher
        // *before* cutting, so continuous batching actually coalesces.
        let mut cuts: Vec<Batch> = Vec::new();
        if let Some(c) = cmd {
            ingest(&mut s, c, &mut cuts, &mut shutdown);
            while let Ok(more) = rx.try_recv() {
                ingest(&mut s, more, &mut cuts, &mut shutdown);
            }
        } else if let Some(b) = s.batcher.poll(Instant::now()) {
            cuts.push(b);
        }
        s.drain_deferred();
        for batch in cuts {
            s.dispatch_or_defer(batch);
        }
        // drain any additional ready batches (the batcher hands them
        // out in EDF cut order across networks)
        while let Some(batch) = s.batcher.poll(Instant::now()) {
            s.dispatch_or_defer(batch);
        }
        if shutdown {
            break 'outer;
        }
    }
    // flush whatever is still queued or deferred, then stop the lanes
    let flush_at = Instant::now() + batcher_cfg.max_wait + Duration::from_secs(1);
    while s.batcher.queued() > 0 {
        match s.batcher.poll(flush_at) {
            Some(batch) => s.dispatch_or_defer(batch),
            None => break,
        }
    }
    let give_up = Instant::now() + Duration::from_secs(10);
    while !s.deferred.is_empty() && Instant::now() < give_up {
        s.drain_deferred();
        if !s.deferred.is_empty() {
            std::thread::sleep(retry_tick);
        }
    }
    for lane in &s.lanes {
        let _ = lane.tx.send(LaneCmd::Shutdown);
    }
    for h in exec_handles {
        let _ = h.join();
    }
}

fn ingest(
    s: &mut Scheduler,
    cmd: LeaderCmd,
    cuts: &mut Vec<Batch>,
    shutdown: &mut bool,
) {
    match cmd {
        LeaderCmd::Submit(mut req, reply) => {
            let now = Instant::now();
            // lifecycle stamp: intake — also re-bases a spilled
            // request's arrival into this site's clock
            req.ctx
                .stamps
                .on_ingest(&s.clock, req.ctx.arrival, now, req.ctx.seed);
            // admission control (4a): with this much work already
            // waiting for lane capacity, reject instead of queueing
            // unboundedly — the low class yields its budget first
            // (the caller observes a typed in-band denial)
            let budget = (s.cfg.admit_max_deferred as f64
                * req.ctx.class.shed_fraction())
            .ceil() as usize;
            if s.deferred.len() >= budget.max(1) {
                s.metrics.lock().unwrap().record_rejected();
                let _ = reply.send(RequestOutcome::Rejected { ctx: req.ctx });
                return;
            }
            // shed-early (4b): a deadline no capable lane can meet is
            // turned away at arrival, not served late
            if s.intake_infeasible(&req, now) {
                s.metrics.lock().unwrap().record_shed(req.ctx.class);
                let _ = reply.send(RequestOutcome::Shed { ctx: req.ctx });
                return;
            }
            // lifecycle stamp: admitted (the gap to ingest is the
            // admission checks' own cost)
            req.ctx.stamps.on_admit(&s.clock, Instant::now());
            // refresh the live cost hint the batcher's slack cutting
            // (and the deferred queue's EDF order) runs on
            if let Some(cm) = s.cheapest_cost(&req.network, req.n_images) {
                s.batcher.set_cost_hint(&req.network, cm);
            }
            s.waiters.insert(req.id, reply);
            if let Some(b) = s.batcher.push(req, now) {
                cuts.push(b);
            }
        }
        LeaderCmd::Shutdown => *shutdown = true,
    }
}
