//! Dynamic batcher — vLLM-style continuous batching adapted to the AOT
//! reality (the generator executables exist at fixed batch buckets), now
//! **deadline-aware**: per-network queues are EDF-ordered (earliest
//! effective deadline first, priority class breaking ties), and a
//! partial batch is cut when the earliest request's *slack* — deadline
//! minus the predicted batch cost from the live per-lane cost model —
//! runs out, not on a fixed max-wait.  `max_wait` survives as the
//! coalescing horizon: a slack-rich (or best-effort) request still cuts
//! at `arrival + max_wait`, so deadline pressure can only *advance* a
//! cut, never delay it.  Pure state machine — time and cost models are
//! injected, so tests are deterministic and the leader loop stays
//! trivial.

use super::request::InferenceRequest;
use crate::backend::CostModel;
use crate::telemetry::RunClock;
use std::collections::HashMap;
use std::time::{Duration, Instant};

/// Headroom factor on the predicted batch cost when converting a
/// deadline into a cut time: cutting at `deadline - HEADROOM × cost`
/// leaves room for dispatch, queueing behind an in-flight batch and the
/// device's measurement noise — cutting at exactly `deadline - cost`
/// would land every completion *on* the deadline and turn model noise
/// into misses.
const SLACK_HEADROOM: f64 = 1.5;

/// Batching policy.
#[derive(Debug, Clone, Copy)]
pub struct BatcherConfig {
    /// Largest exported batch bucket (images per executable call).
    pub max_batch: usize,
    /// Coalescing horizon: max time a queued request may wait before a
    /// partial batch is cut, independent of any deadline.
    pub max_wait: Duration,
}

impl Default for BatcherConfig {
    fn default() -> Self {
        BatcherConfig {
            max_batch: 8,
            max_wait: Duration::from_millis(5),
        }
    }
}

/// A cut batch: requests (in serve order) plus the image count they
/// need and the earliest real deadline aboard (the EDF key the
/// scheduler re-sorts deferred batches by).
#[derive(Debug)]
pub struct Batch {
    pub network: String,
    pub requests: Vec<InferenceRequest>,
    pub n_images: usize,
    /// Earliest absolute deadline among the requests (`None` = all
    /// best-effort).
    pub deadline: Option<Instant>,
}

/// Per-network EDF request queues with slack-based cutting.
#[derive(Debug, Default)]
pub struct DynamicBatcher {
    /// Each queue is kept sorted by (effective deadline, class rank,
    /// id) — EDF with class tie-break; insertion is before the first
    /// strictly-greater key, so equal-deadline requests stay in
    /// arrival order.
    queues: HashMap<String, Vec<InferenceRequest>>,
    /// Live per-network cost hints (cheapest capable lane), refreshed
    /// by the scheduler on intake — the "predicted cost" half of the
    /// slack computation.
    costs: HashMap<String, CostModel>,
    config: BatcherConfig,
    /// Clock the cut stamp (queue-wait → batch-form boundary) is taken
    /// against; injected so fleet sites stamp in their own skewed time.
    clock: RunClock,
}

/// EDF ordering key of one queued request.
fn edf_key(r: &InferenceRequest, max_wait: Duration) -> (Instant, u8, u64) {
    (r.ctx.effective_deadline(max_wait), r.ctx.class.rank(), r.id)
}

impl DynamicBatcher {
    pub fn new(config: BatcherConfig) -> Self {
        Self::with_clock(config, RunClock::default())
    }

    /// A batcher stamping cut times against an explicit run clock (the
    /// coordinator passes its site clock so lifecycle spans cohere).
    pub fn with_clock(config: BatcherConfig, clock: RunClock) -> Self {
        DynamicBatcher {
            queues: HashMap::new(),
            costs: HashMap::new(),
            config,
            clock,
        }
    }

    /// Install/refresh the live cost model for a network (the cheapest
    /// capable lane's, per the scheduler).  Without a hint the batcher
    /// predicts zero cost and slack cutting degrades to the max-wait
    /// horizon — exactly the old behaviour.
    pub fn set_cost_hint(&mut self, network: &str, cost: CostModel) {
        match self.costs.get_mut(network) {
            Some(c) => *c = cost,
            None => {
                self.costs.insert(network.to_string(), cost);
            }
        }
    }

    /// The current cost hint for a network (scheduler-side slack
    /// queries on deferred batches reuse it).
    pub fn cost_hint(&self, network: &str) -> Option<CostModel> {
        self.costs.get(network).copied()
    }

    /// Predicted device cost of cutting `n_images` of `network` now.
    fn predicted_cost_s(&self, network: &str, n_images: usize) -> f64 {
        self.costs
            .get(network)
            .map(|c| c.cost_s(n_images))
            .unwrap_or(0.0)
    }

    /// Enqueue a request in EDF position; returns a batch only if a
    /// bucket *filled* — waiting requests are left to coalesce until
    /// [`Self::poll`]'s cut time fires (cutting on push-side expiry
    /// would emit tiny batches whenever the device briefly falls
    /// behind).
    pub fn push(&mut self, req: InferenceRequest, now: Instant) -> Option<Batch> {
        let max_wait = self.config.max_wait;
        let key = edf_key(&req, max_wait);
        match self.queues.get_mut(req.network.as_str()) {
            Some(q) => {
                let pos = q
                    .iter()
                    .position(|r| edf_key(r, max_wait) > key)
                    .unwrap_or(q.len());
                q.insert(pos, req);
            }
            None => {
                let name = req.network.clone();
                self.queues.insert(name, vec![req]);
            }
        }
        self.try_cut(now, false)
    }

    /// Cut poll: a full bucket, or a partial batch whose cut time (the
    /// earliest request's slack or max-wait horizon) has passed.
    pub fn poll(&mut self, now: Instant) -> Option<Batch> {
        self.try_cut(now, true)
    }

    /// Total queued requests (all networks).
    pub fn queued(&self) -> usize {
        self.queues.values().map(|q| q.len()).sum()
    }

    /// When one network's partial batch must be cut: the minimum over
    /// its queued requests of `min(arrival + max_wait, deadline -
    /// HEADROOM × predicted batch cost)` — deadline pressure advances
    /// the cut, the horizon bounds the wait.
    fn cut_at(&self, network: &str, q: &[InferenceRequest]) -> Option<Instant> {
        let images: usize = q.iter().map(|r| r.n_images).sum();
        let batch_images = images.min(self.config.max_batch).max(1);
        let cost = self.predicted_cost_s(network, batch_images);
        let lead = Duration::from_secs_f64(SLACK_HEADROOM * cost);
        q.iter()
            .map(|r| {
                let horizon = r.ctx.arrival + self.config.max_wait;
                match r.ctx.deadline {
                    Some(d) => {
                        // clamp: a deadline already inside the lead time
                        // means the slack is spent — cut immediately
                        let slack_cut =
                            d.checked_sub(lead).unwrap_or(r.ctx.arrival);
                        horizon.min(slack_cut.max(r.ctx.arrival))
                    }
                    None => horizon,
                }
            })
            .min()
    }

    /// Earliest cut time among queued requests (for the leader loop's
    /// sleep).
    pub fn next_deadline(&self) -> Option<Instant> {
        self.queues
            .iter()
            .filter_map(|(net, q)| self.cut_at(net, q))
            .min()
    }

    /// Cut one batch: full buckets always qualify; slack/horizon-expired
    /// partials only on the poll path.  Among ready networks the one
    /// with the earliest cut time wins — EDF *across* networks, where
    /// the old batcher took hash-map iteration order.
    fn try_cut(&mut self, now: Instant, allow_expired: bool) -> Option<Batch> {
        let mut chosen: Option<(Instant, String)> = None;
        for (net, q) in &self.queues {
            if q.is_empty() {
                continue;
            }
            let images: usize = q.iter().map(|r| r.n_images).sum();
            let ready_at = if images >= self.config.max_batch {
                now // a full bucket cuts immediately
            } else if allow_expired {
                // partial bucket: only the poll path pays for the
                // per-request cut-time scan
                let cut_at = self.cut_at(net, q).expect("non-empty queue");
                if cut_at <= now {
                    cut_at
                } else {
                    continue;
                }
            } else {
                continue;
            };
            let better = match &chosen {
                None => true,
                Some((t, name)) => {
                    (ready_at, net.as_str()) < (*t, name.as_str())
                }
            };
            if better {
                chosen = Some((ready_at, net.clone()));
            }
        }
        let (_, net) = chosen?;
        Some(self.cut_network(&net, now))
    }

    /// Cut the front of one network's queue into a batch.  Serve order
    /// is EDF with one twist (skip-over EDF): requests whose deadline is
    /// already infeasible — `now + predicted cost > deadline` — yield to
    /// every still-feasible request, because an already-late request
    /// cannot get *less* late while a feasible one can still make it.
    /// In particular a feasible request is never served after an
    /// infeasible one of the same priority class (property-tested).
    fn cut_network(&mut self, net: &str, now: Instant) -> Batch {
        let images_queued: usize = self.queues[net]
            .iter()
            .map(|r| r.n_images)
            .sum();
        let batch_images = images_queued.min(self.config.max_batch).max(1);
        let cost = self.predicted_cost_s(net, batch_images);
        let max_wait = self.config.max_wait;
        let q = self.queues.get_mut(net).expect("chosen network exists");

        let infeasible = |r: &InferenceRequest| -> bool {
            match r.ctx.deadline {
                Some(d) => now + Duration::from_secs_f64(cost) > d,
                None => false,
            }
        };
        let mut order: Vec<usize> = (0..q.len()).collect();
        order.sort_by_key(|&i| {
            let r = &q[i];
            (
                infeasible(r),
                r.ctx.effective_deadline(max_wait),
                r.ctx.class.rank(),
                r.id,
            )
        });

        let mut take: Vec<usize> = Vec::new();
        let mut images = 0usize;
        for &i in &order {
            let n = q[i].n_images;
            if images + n > self.config.max_batch && !take.is_empty() {
                break;
            }
            take.push(i);
            images += n;
            if images >= self.config.max_batch {
                break;
            }
        }

        let mut slots: Vec<Option<InferenceRequest>> =
            q.drain(..).map(Some).collect();
        let mut requests: Vec<InferenceRequest> = take
            .iter()
            .map(|&i| slots[i].take().expect("indices are unique"))
            .collect();
        // the untaken remainder keeps its EDF order
        q.extend(slots.into_iter().flatten());
        // lifecycle stamp: the cut ends these requests' EDF queue wait
        for r in &mut requests {
            r.ctx.stamps.on_cut(&self.clock, now);
        }

        let deadline = requests.iter().filter_map(|r| r.ctx.deadline).min();
        Batch {
            network: net.to_string(),
            requests,
            n_images: images,
            deadline,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::request::{PriorityClass, RequestCtx};

    fn req(id: u64, net: &str, n: usize) -> InferenceRequest {
        InferenceRequest::new(id, net, n, id)
    }

    fn req_deadline(
        id: u64,
        net: &str,
        n: usize,
        arrival: Instant,
        deadline_ms: u64,
    ) -> InferenceRequest {
        let ctx = RequestCtx {
            arrival,
            deadline: Some(arrival + Duration::from_millis(deadline_ms)),
            class: PriorityClass::Normal,
            seed: id,
            stamps: Default::default(),
        };
        InferenceRequest::with_ctx(id, net, n, ctx)
    }

    fn cfg(max_batch: usize, wait_ms: u64) -> BatcherConfig {
        BatcherConfig {
            max_batch,
            max_wait: Duration::from_millis(wait_ms),
        }
    }

    #[test]
    fn full_bucket_cuts_immediately() {
        let mut b = DynamicBatcher::new(cfg(4, 1000));
        let now = Instant::now();
        assert!(b.push(req(1, "mnist", 2), now).is_none());
        let batch = b.push(req(2, "mnist", 2), now).expect("bucket full");
        assert_eq!(batch.n_images, 4);
        assert_eq!(batch.requests.len(), 2);
        assert!(batch.deadline.is_none(), "best-effort batch");
        assert_eq!(b.queued(), 0);
    }

    #[test]
    fn partial_batch_waits_for_deadline() {
        let mut b = DynamicBatcher::new(cfg(8, 10));
        let now = Instant::now();
        assert!(b.push(req(1, "mnist", 2), now).is_none());
        assert!(b.poll(now).is_none(), "window not expired");
        let later = now + Duration::from_millis(11);
        let batch = b.poll(later).expect("window expired");
        assert_eq!(batch.n_images, 2);
    }

    #[test]
    fn networks_batch_independently() {
        let mut b = DynamicBatcher::new(cfg(4, 1000));
        let now = Instant::now();
        assert!(b.push(req(1, "mnist", 2), now).is_none());
        assert!(b.push(req(2, "celeba", 2), now).is_none());
        let batch = b.push(req(3, "mnist", 2), now).expect("mnist full");
        assert_eq!(batch.network, "mnist");
        assert_eq!(b.queued(), 1, "celeba still queued");
    }

    #[test]
    fn oversize_request_cut_alone() {
        let mut b = DynamicBatcher::new(cfg(4, 1000));
        let now = Instant::now();
        let batch = b.push(req(1, "mnist", 9), now).expect("cut");
        assert_eq!(batch.requests.len(), 1);
        assert_eq!(batch.n_images, 9);
    }

    #[test]
    fn batch_respects_bucket_boundary() {
        let mut b = DynamicBatcher::new(cfg(4, 1000));
        let now = Instant::now();
        b.push(req(1, "mnist", 3), now);
        // 3 + 3 > 4 → first batch cut holds only request 1 … 3+3 over
        // bucket: second stays queued
        let batch = b.push(req(2, "mnist", 3), now).expect("cut at bucket");
        assert_eq!(batch.requests.len(), 1);
        assert_eq!(b.queued(), 1);
    }

    #[test]
    fn next_deadline_tracks_oldest() {
        let mut b = DynamicBatcher::new(cfg(8, 10));
        assert!(b.next_deadline().is_none());
        let now = Instant::now();
        b.push(req(1, "mnist", 1), now);
        let d = b.next_deadline().unwrap();
        assert!(d > now);
    }

    #[test]
    fn poll_with_empty_queues_is_a_noop() {
        let mut b = DynamicBatcher::new(cfg(4, 10));
        let now = Instant::now();
        assert!(b.poll(now).is_none(), "nothing queued, nothing cut");
        assert!(b.poll(now + Duration::from_secs(1)).is_none());
        assert!(b.next_deadline().is_none());
        assert_eq!(b.queued(), 0);
        // a network whose queue drained completely behaves like empty
        let batch = b.push(req(1, "mnist", 4), now).expect("full bucket");
        assert_eq!(batch.n_images, 4);
        assert!(b.poll(now + Duration::from_secs(1)).is_none());
    }

    #[test]
    fn interleaved_networks_each_get_their_batch() {
        // fairness: interleaved pushes to two networks never merge
        // across networks, and *both* expire at the deadline — one poll
        // per network drains them
        let mut b = DynamicBatcher::new(cfg(8, 10));
        let now = Instant::now();
        for i in 0..3u64 {
            assert!(b.push(req(2 * i, "mnist", 1), now).is_none());
            assert!(b.push(req(2 * i + 1, "celeba", 1), now).is_none());
        }
        let later = now + Duration::from_millis(11);
        let first = b.poll(later).expect("first network expired");
        let second = b.poll(later).expect("second network expired");
        assert_ne!(first.network, second.network);
        for batch in [&first, &second] {
            assert_eq!(batch.requests.len(), 3, "{}", batch.network);
            let ids: Vec<u64> = batch.requests.iter().map(|r| r.id).collect();
            let mut sorted = ids.clone();
            sorted.sort_unstable();
            assert_eq!(ids, sorted, "per-network FIFO order survives");
        }
        assert_eq!(b.queued(), 0);
        assert!(b.poll(later).is_none());
    }

    #[test]
    fn partial_batch_cuts_exactly_at_the_boundary() {
        let mut b = DynamicBatcher::new(cfg(8, 10));
        let now = Instant::now();
        let enqueued = {
            b.push(req(1, "mnist", 2), now);
            // the horizon is anchored to the request's arrival time,
            // not the push() timestamp
            b.next_deadline().unwrap() - Duration::from_millis(10)
        };
        let boundary = enqueued + Duration::from_millis(10);
        assert!(
            b.poll(boundary - Duration::from_nanos(1)).is_none(),
            "one tick before the window: no cut"
        );
        let batch = b.poll(boundary).expect("exactly at max_wait: cut");
        assert_eq!(batch.n_images, 2);
    }

    #[test]
    fn edf_orders_the_queue_by_deadline_not_arrival() {
        let mut b = DynamicBatcher::new(cfg(8, 1000));
        let now = Instant::now();
        // arrival order 1, 2, 3 — deadline order 2, 3, 1
        b.push(req_deadline(1, "mnist", 1, now, 90), now);
        b.push(req_deadline(2, "mnist", 1, now, 30), now);
        b.push(req_deadline(3, "mnist", 1, now, 60), now);
        let batch = b.poll(now + Duration::from_secs(2)).expect("expired");
        let ids: Vec<u64> = batch.requests.iter().map(|r| r.id).collect();
        assert_eq!(ids, vec![2, 3, 1], "EDF serve order");
        assert_eq!(
            batch.deadline,
            Some(now + Duration::from_millis(30)),
            "batch carries its earliest deadline"
        );
    }

    #[test]
    fn slack_cut_fires_before_the_max_wait_horizon() {
        let mut b = DynamicBatcher::new(cfg(8, 1000));
        // live cost model: 20 ms per image
        b.set_cost_hint("mnist", CostModel::linear(0.020));
        let now = Instant::now();
        // deadline 100 ms out, predicted cost 20 ms → with 1.5× headroom
        // the cut fires at deadline - 30 ms = now + 70 ms, far before
        // the 1000 ms horizon
        b.push(req_deadline(1, "mnist", 1, now, 100), now);
        let cut = b.next_deadline().unwrap();
        let expect = now + Duration::from_millis(70);
        let delta = if cut > expect { cut - expect } else { expect - cut };
        assert!(
            delta < Duration::from_millis(1),
            "cut time must be slack-driven (off by {delta:?})"
        );
        assert!(b.poll(now + Duration::from_millis(60)).is_none());
        assert!(b.poll(now + Duration::from_millis(71)).is_some());
    }

    #[test]
    fn spent_slack_cuts_immediately() {
        let mut b = DynamicBatcher::new(cfg(8, 1000));
        b.set_cost_hint("mnist", CostModel::linear(0.040));
        let now = Instant::now();
        // 10 ms of budget against a 40 ms predicted cost: the slack is
        // already negative — the poll must cut right away, not wait
        b.push(req_deadline(1, "mnist", 1, now, 10), now);
        assert!(b.poll(now).is_some(), "negative slack cuts immediately");
    }

    #[test]
    fn feasible_requests_cut_ahead_of_infeasible_same_class() {
        let mut b = DynamicBatcher::new(cfg(4, 1000));
        b.set_cost_hint("mnist", CostModel::linear(0.010));
        let now = Instant::now();
        // request 1's deadline (5 ms) is inside the 10 ms predicted
        // cost → infeasible; request 2 (500 ms) can still make it.
        // EDF alone would serve 1 first; skip-over EDF must not.
        b.push(req_deadline(1, "mnist", 1, now, 5), now);
        b.push(req_deadline(2, "mnist", 1, now, 500), now);
        let batch = b.poll(now + Duration::from_millis(6)).expect("cut");
        let ids: Vec<u64> = batch.requests.iter().map(|r| r.id).collect();
        assert_eq!(ids, vec![2, 1], "feasible before infeasible");
    }

    #[test]
    fn class_breaks_equal_deadline_ties() {
        let mut b = DynamicBatcher::new(cfg(8, 1000));
        let now = Instant::now();
        let mk = |id: u64, class: PriorityClass| {
            let ctx = RequestCtx {
                arrival: now,
                deadline: Some(now + Duration::from_millis(50)),
                class,
                seed: id,
                stamps: Default::default(),
            };
            InferenceRequest::with_ctx(id, "mnist", 1, ctx)
        };
        b.push(mk(1, PriorityClass::Low), now);
        b.push(mk(2, PriorityClass::High), now);
        b.push(mk(3, PriorityClass::Normal), now);
        let batch = b.poll(now + Duration::from_secs(1)).expect("expired");
        let classes: Vec<PriorityClass> =
            batch.requests.iter().map(|r| r.ctx.class).collect();
        assert_eq!(
            classes,
            vec![
                PriorityClass::High,
                PriorityClass::Normal,
                PriorityClass::Low
            ],
            "equal deadlines: higher class first"
        );
    }
}
