//! Dynamic batcher — vLLM-style continuous batching adapted to the AOT
//! reality: the generator executables exist at fixed batch buckets
//! (`make artifacts` exports them), so the batcher coalesces queued
//! requests per network and cuts a batch when (a) a full bucket's worth
//! of images is waiting, or (b) the oldest request exceeds the batching
//! window.  Pure state machine — time is injected, so tests are
//! deterministic and the tokio loop stays trivial.

use super::request::InferenceRequest;
use std::collections::{HashMap, VecDeque};
use std::time::{Duration, Instant};

/// Batching policy.
#[derive(Debug, Clone, Copy)]
pub struct BatcherConfig {
    /// Largest exported batch bucket (images per executable call).
    pub max_batch: usize,
    /// Max time the oldest queued request may wait before a partial
    /// batch is cut.
    pub max_wait: Duration,
}

impl Default for BatcherConfig {
    fn default() -> Self {
        BatcherConfig {
            max_batch: 8,
            max_wait: Duration::from_millis(5),
        }
    }
}

/// A cut batch: requests plus the image count they need.
#[derive(Debug)]
pub struct Batch {
    pub network: String,
    pub requests: Vec<InferenceRequest>,
    pub n_images: usize,
}

/// Per-network request queues with deadline-based cutting.
#[derive(Debug, Default)]
pub struct DynamicBatcher {
    queues: HashMap<String, VecDeque<InferenceRequest>>,
    config: BatcherConfig,
}

impl DynamicBatcher {
    pub fn new(config: BatcherConfig) -> Self {
        DynamicBatcher {
            queues: HashMap::new(),
            config,
        }
    }

    /// Enqueue a request; returns a batch only if a bucket *filled* —
    /// waiting requests are left to coalesce until [`Self::poll`]'s
    /// deadline fires (cutting on push-side expiry would emit tiny
    /// batches whenever the device briefly falls behind).
    ///
    /// Hot path: the queue lookup is by borrowed name — the network
    /// `String` is only cloned the first time a network is seen.
    pub fn push(&mut self, req: InferenceRequest, _now: Instant) -> Option<Batch> {
        match self.queues.get_mut(req.network.as_str()) {
            Some(q) => q.push_back(req),
            None => {
                let name = req.network.clone();
                self.queues.insert(name, VecDeque::from([req]));
            }
        }
        self.try_cut(None)
    }

    /// Deadline poll: cut a full bucket, or a partial batch whose oldest
    /// request waited past the window.
    pub fn poll(&mut self, now: Instant) -> Option<Batch> {
        self.try_cut(Some(now))
    }

    /// Total queued requests (all networks).
    pub fn queued(&self) -> usize {
        self.queues.values().map(|q| q.len()).sum()
    }

    /// Earliest deadline among queued requests (for the serve loop's
    /// sleep).
    pub fn next_deadline(&self) -> Option<Instant> {
        self.queues
            .values()
            .filter_map(|q| q.front())
            .map(|r| r.enqueued_at + self.config.max_wait)
            .min()
    }

    /// Cut a batch: full buckets always qualify; expired partials only
    /// when a deadline clock is supplied (poll path).
    fn try_cut(&mut self, deadline_now: Option<Instant>) -> Option<Batch> {
        let mut chosen: Option<String> = None;
        for (net, q) in &self.queues {
            let Some(front) = q.front() else { continue };
            let images: usize = q.iter().map(|r| r.n_images).sum();
            let full = images >= self.config.max_batch;
            let expired = deadline_now
                .map(|now| {
                    now.duration_since(front.enqueued_at)
                        >= self.config.max_wait
                })
                .unwrap_or(false);
            if full || expired {
                chosen = Some(net.clone());
                break;
            }
        }
        let net = chosen?;
        let q = self.queues.get_mut(&net).unwrap();
        let mut requests = Vec::new();
        let mut images = 0usize;
        while let Some(front) = q.front() {
            if images + front.n_images > self.config.max_batch
                && !requests.is_empty()
            {
                break;
            }
            let r = q.pop_front().unwrap();
            images += r.n_images;
            requests.push(r);
            if images >= self.config.max_batch {
                break;
            }
        }
        if requests.is_empty() {
            return None;
        }
        Some(Batch {
            network: net,
            requests,
            n_images: images,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn req(id: u64, net: &str, n: usize) -> InferenceRequest {
        InferenceRequest::new(id, net, n, id)
    }

    fn cfg(max_batch: usize, wait_ms: u64) -> BatcherConfig {
        BatcherConfig {
            max_batch,
            max_wait: Duration::from_millis(wait_ms),
        }
    }

    #[test]
    fn full_bucket_cuts_immediately() {
        let mut b = DynamicBatcher::new(cfg(4, 1000));
        let now = Instant::now();
        assert!(b.push(req(1, "mnist", 2), now).is_none());
        let batch = b.push(req(2, "mnist", 2), now).expect("bucket full");
        assert_eq!(batch.n_images, 4);
        assert_eq!(batch.requests.len(), 2);
        assert_eq!(b.queued(), 0);
    }

    #[test]
    fn partial_batch_waits_for_deadline() {
        let mut b = DynamicBatcher::new(cfg(8, 10));
        let now = Instant::now();
        assert!(b.push(req(1, "mnist", 2), now).is_none());
        assert!(b.poll(now).is_none(), "window not expired");
        let later = now + Duration::from_millis(11);
        let batch = b.poll(later).expect("window expired");
        assert_eq!(batch.n_images, 2);
    }

    #[test]
    fn networks_batch_independently() {
        let mut b = DynamicBatcher::new(cfg(4, 1000));
        let now = Instant::now();
        assert!(b.push(req(1, "mnist", 2), now).is_none());
        assert!(b.push(req(2, "celeba", 2), now).is_none());
        let batch = b.push(req(3, "mnist", 2), now).expect("mnist full");
        assert_eq!(batch.network, "mnist");
        assert_eq!(b.queued(), 1, "celeba still queued");
    }

    #[test]
    fn oversize_request_cut_alone() {
        let mut b = DynamicBatcher::new(cfg(4, 1000));
        let now = Instant::now();
        let batch = b.push(req(1, "mnist", 9), now).expect("cut");
        assert_eq!(batch.requests.len(), 1);
        assert_eq!(batch.n_images, 9);
    }

    #[test]
    fn batch_respects_bucket_boundary() {
        let mut b = DynamicBatcher::new(cfg(4, 1000));
        let now = Instant::now();
        b.push(req(1, "mnist", 3), now);
        // 3 + 3 > 4 → first batch cut holds only request 1 … 3+3 over
        // bucket: second stays queued
        let batch = b.push(req(2, "mnist", 3), now).expect("cut at bucket");
        assert_eq!(batch.requests.len(), 1);
        assert_eq!(b.queued(), 1);
    }

    #[test]
    fn next_deadline_tracks_oldest() {
        let mut b = DynamicBatcher::new(cfg(8, 10));
        assert!(b.next_deadline().is_none());
        let now = Instant::now();
        b.push(req(1, "mnist", 1), now);
        let d = b.next_deadline().unwrap();
        assert!(d > now);
    }

    #[test]
    fn poll_with_empty_queues_is_a_noop() {
        let mut b = DynamicBatcher::new(cfg(4, 10));
        let now = Instant::now();
        assert!(b.poll(now).is_none(), "nothing queued, nothing cut");
        assert!(b.poll(now + Duration::from_secs(1)).is_none());
        assert!(b.next_deadline().is_none());
        assert_eq!(b.queued(), 0);
        // a network whose queue drained completely behaves like empty
        let batch = b.push(req(1, "mnist", 4), now).expect("full bucket");
        assert_eq!(batch.n_images, 4);
        assert!(b.poll(now + Duration::from_secs(1)).is_none());
    }

    #[test]
    fn interleaved_networks_each_get_their_batch() {
        // fairness: interleaved pushes to two networks never merge
        // across networks, and *both* expire at the deadline — one poll
        // per network drains them
        let mut b = DynamicBatcher::new(cfg(8, 10));
        let now = Instant::now();
        for i in 0..3u64 {
            assert!(b.push(req(2 * i, "mnist", 1), now).is_none());
            assert!(b.push(req(2 * i + 1, "celeba", 1), now).is_none());
        }
        let later = now + Duration::from_millis(11);
        let first = b.poll(later).expect("first network expired");
        let second = b.poll(later).expect("second network expired");
        assert_ne!(first.network, second.network);
        for batch in [&first, &second] {
            assert_eq!(batch.requests.len(), 3, "{}", batch.network);
            let ids: Vec<u64> = batch.requests.iter().map(|r| r.id).collect();
            let mut sorted = ids.clone();
            sorted.sort_unstable();
            assert_eq!(ids, sorted, "per-network FIFO order survives");
        }
        assert_eq!(b.queued(), 0);
        assert!(b.poll(later).is_none());
    }

    #[test]
    fn partial_batch_cuts_exactly_at_the_boundary() {
        let mut b = DynamicBatcher::new(cfg(8, 10));
        let now = Instant::now();
        let enqueued = {
            b.push(req(1, "mnist", 2), now);
            // the deadline is anchored to the request's enqueue time,
            // not the push() timestamp
            b.next_deadline().unwrap() - Duration::from_millis(10)
        };
        let boundary = enqueued + Duration::from_millis(10);
        assert!(
            b.poll(boundary - Duration::from_nanos(1)).is_none(),
            "one tick before the window: no cut"
        );
        let batch = b.poll(boundary).expect("exactly at max_wait: cut");
        assert_eq!(batch.n_images, 2);
    }
}
