//! Analytical resource model — Table I and the DSE legality check.
//!
//! The model mirrors how the HLS design consumes the Zynq-7020 fabric:
//!
//! * **DSP48** — `8 per CU` (MAC lanes) + 6 for address generation and
//!   the offset pre-computation unit.  Independent of `T_OH`, which is
//!   why both Table I rows report 134.
//! * **BRAM18** — per-CU double-buffered input tile (`T_IH²`, Eq. 5, at
//!   the network's worst-case layer) and output tile (`T_OH²`) ping-pong
//!   buffers, plus a fixed infrastructure pool (AXI DMA staging, weight
//!   FIFOs, offset LUT).
//! * **FF/LUT** — linear in the CU count with a `T_OH`-dependent term
//!   (wider address counters, deeper line buffers).  Coefficients are
//!   calibrated against the paper's Vivado reports (Table I) and
//!   documented below; the *scaling laws* are what the DSE consumes.
//!
//! Calibration quality (documented, also asserted in tests):
//! MNIST row reproduced exactly (134/50/43218/36469 → model
//! 134/50/43218/36469); CelebA row within 11% on BRAM (66 vs 74) and
//! <0.1% on FF/LUT.  The BRAM gap is Vivado packing slack the linear
//! model does not capture; see EXPERIMENTS.md §Table I.

use crate::config::{FpgaBoard, NetworkCfg, Precision};
use crate::deconv::input_tile_extent;

/// Bytes per BRAM18 block (18 Kbit).
const BRAM18_BYTES: usize = 2304;
/// DSP48 MAC lanes per CU.
const DSP_PER_CU: usize = 8;
/// DSPs for address generation + offset precompute unit.
const DSP_INFRA: usize = 6;
/// BRAM18 blocks for AXI DMA staging, weight FIFOs and the offset LUT.
const BRAM_INFRA: usize = 18;
/// FF cost: per CU / per unit of T_OH / fixed control.
const FF_PER_CU: usize = 2000;
const FF_PER_T: usize = 477;
const FF_BASE: usize = 5494;
/// LUT cost: per CU / per unit of T_OH / fixed control.
const LUT_PER_CU: usize = 1700;
const LUT_PER_T: usize = 371;
const LUT_BASE: usize = 4817;

/// Estimated fabric utilization of the accelerator at one design point.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Utilization {
    pub dsp: usize,
    pub bram18: usize,
    pub ff: usize,
    pub lut: usize,
}

impl Utilization {
    /// Does the design fit the device?
    pub fn fits(&self, board: &FpgaBoard) -> bool {
        self.dsp <= board.dsp_total
            && self.bram18 <= board.bram18_total
            && self.ff <= board.ff_total
            && self.lut <= board.lut_total
    }
}

/// Estimate resources for `n_cu` CUs at output tile factor `t_oh` for a
/// network at the f32 datapath (the historical Table I configuration).
pub fn estimate_resources(
    net: &NetworkCfg,
    t_oh: usize,
    n_cu: usize,
) -> Utilization {
    estimate_resources_at(net, t_oh, n_cu, Precision::F32)
}

/// [`estimate_resources`] at an explicit datapath precision: the BRAM
/// input buffers store *element-width* words, the output ping-pong
/// buffers store *accumulator-width* words (the tile lives in the DSP48
/// accumulator domain until the round/saturate write-back), and the
/// per-CU fabric cost scales with the datapath width.  The worst-case
/// layer sizes the buffers, since the accelerator multiplexes all
/// layers through one configuration.
pub fn estimate_resources_at(
    net: &NetworkCfg,
    t_oh: usize,
    n_cu: usize,
    precision: Precision,
) -> Utilization {
    // worst-case input tile across layers (Eq. 5 with each layer's K, S)
    let t_i_max = net
        .layers
        .iter()
        .map(|l| input_tile_extent(t_oh.min(l.o_h()).max(1), l.k, l.stride))
        .max()
        .unwrap_or(1);
    let t_eff = net
        .layers
        .iter()
        .map(|l| t_oh.min(l.o_h()).max(1))
        .max()
        .unwrap_or(t_oh);

    // input tile single-buffered (sequential stream-in) at the element
    // width; output tile ping-pong double-buffered at the *accumulator*
    // width so the one-shot write overlaps the next tile's compute
    // (stage 3 of the pipeline)
    let eb = precision.elem_bytes() as usize;
    let ab = precision.acc_bytes() as usize;
    let in_buf = (eb * t_i_max * t_i_max).div_ceil(BRAM18_BYTES);
    let out_buf = (2 * ab * t_eff * t_eff).div_ceil(BRAM18_BYTES);
    let bram = BRAM_INFRA + n_cu * (in_buf + out_buf);

    // Per-CU fabric scales with datapath width: 8-bit operand muxing
    // and byte-wide line buffers trim ~3/8 of the CU fabric (the ×4
    // DSP packing adds back a little routing over a naive byte path);
    // 16-bit multiplier trees and narrower muxing trim ~1/4; a 32-bit
    // integer datapath with its 64-bit accumulator chain costs slightly
    // more than f32 (calibrated guesses on the same footing as the base
    // coefficients — the *scaling law* is what the DSE consumes).
    let (num, den): (usize, usize) = match precision {
        Precision::F32 => (1, 1),
        Precision::Fixed(q) if q.bits <= 8 => (5, 8),
        Precision::Fixed(q) if q.bits <= 16 => (3, 4),
        Precision::Fixed(_) => (9, 8),
    };

    Utilization {
        dsp: n_cu * DSP_PER_CU + DSP_INFRA,
        bram18: bram,
        ff: FF_BASE + n_cu * FF_PER_CU * num / den + FF_PER_T * t_eff,
        lut: LUT_BASE + n_cu * LUT_PER_CU * num / den + LUT_PER_T * t_eff,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{celeba, mnist, PYNQ_Z2};

    #[test]
    fn table1_mnist_row_exact() {
        let u = estimate_resources(&mnist(), 12, 16);
        assert_eq!(u.dsp, 134);
        assert_eq!(u.bram18, 50);
        assert_eq!(u.ff, 43218);
        assert_eq!(u.lut, 36469);
        assert!(u.fits(&PYNQ_Z2));
    }

    #[test]
    fn table1_celeba_row_close() {
        let u = estimate_resources(&celeba(), 24, 16);
        assert_eq!(u.dsp, 134);
        // paper: 74 — linear model lands at 66 (11% under; see module doc)
        assert!((u.bram18 as i64 - 74).unsigned_abs() <= 10, "bram={}", u.bram18);
        assert!((u.ff as i64 - 48938).unsigned_abs() <= 200, "ff={}", u.ff);
        assert!((u.lut as i64 - 40923).unsigned_abs() <= 200, "lut={}", u.lut);
        assert!(u.fits(&PYNQ_Z2));
    }

    #[test]
    fn dsp_independent_of_tile() {
        let a = estimate_resources(&mnist(), 4, 16);
        let b = estimate_resources(&mnist(), 24, 16);
        assert_eq!(a.dsp, b.dsp);
    }

    #[test]
    fn bram_monotone_in_tile() {
        let net = celeba();
        let mut prev = 0;
        for t in [4, 8, 16, 24, 32, 48, 64] {
            let u = estimate_resources(&net, t, 16);
            assert!(u.bram18 >= prev, "bram must grow with T");
            prev = u.bram18;
        }
    }

    #[test]
    fn fixed_point_shrinks_the_fabric_footprint() {
        use crate::config::QFormat;
        let q16 = Precision::Fixed(QFormat::new(16, 8));
        for net in [mnist(), celeba()] {
            let f = estimate_resources_at(&net, net.tile, 16, Precision::F32);
            let q = estimate_resources_at(&net, net.tile, 16, q16);
            assert_eq!(q.dsp, f.dsp, "same DSP budget (lanes pack, not grow)");
            assert!(q.ff < f.ff);
            assert!(q.lut < f.lut);
            // BRAM trades: half-width input/AXI words vs the 48-bit
            // accumulator ping-pong — net within one block per CU
            assert!(q.bram18 <= f.bram18 + 16, "bram {} vs {}", q.bram18, f.bram18);
            assert!(q.fits(&PYNQ_Z2));
        }
    }

    #[test]
    fn int8_packs_lanes_without_spending_dsps() {
        use crate::config::QFormat;
        let q8 = Precision::Fixed(QFormat::new(8, 6));
        for net in [mnist(), celeba()] {
            let f = estimate_resources_at(&net, net.tile, 16, Precision::F32);
            let q = estimate_resources_at(&net, net.tile, 16, q8);
            // DSP count flat vs f32 while the MAC lanes quadruple —
            // the ×4 packing rides the same DSP budget
            assert_eq!(q.dsp, f.dsp, "i8 packs into the same DSPs");
            assert_eq!(q8.lane_factor(), 4 * Precision::F32.lane_factor());
            // 1-byte elements: input buffers shrink vs both f32 and q16
            let q16 = estimate_resources_at(
                &net,
                net.tile,
                16,
                Precision::Fixed(QFormat::new(16, 8)),
            );
            // byte-true buffer sizing (1-byte elements, i32 acc) can
            // only shrink the block counts, never grow them
            assert!(q.bram18 <= q16.bram18, "{} vs {}", q.bram18, q16.bram18);
            assert!(q.bram18 <= f.bram18, "{} vs {}", q.bram18, f.bram18);
            assert!(q.ff < q16.ff && q.lut < q16.lut);
            assert!(q.fits(&PYNQ_Z2));
        }
    }

    #[test]
    fn oversized_design_does_not_fit() {
        // 64 CUs blows the DSP budget of the -7020
        let u = estimate_resources(&mnist(), 12, 64);
        assert!(!u.fits(&PYNQ_Z2));
    }
}
