//! Compute-unit cycle model — stage (2) of the pipeline.
//!
//! Each CU executes Algorithm 1 for one `(output tile, output channel)`
//! workload: loop over input channels, then the weight taps (weight-
//! stationary, enhancement 2), issuing `(T/S)²` MACs per tap across its
//! DSP lanes.  Zero-skipping replaces a tap's MACs with a single weight
//! test cycle (the conditional-execution paradigm of Section V-C).
//!
//! The datapath precision scales the MAC lane count
//! ([`Precision::lane_factor`]): two 16-bit fixed-point MACs pack into
//! one DSP48, so the same DSP budget issues twice the MACs per cycle —
//! the width/throughput trade the quantized path buys.

use crate::config::{FpgaBoard, Precision};
use crate::deconv::BlockSchedule;
use crate::util::WorkerPool;

/// One CU workload: a `T_OH × T_OW` output block for one output channel.
#[derive(Debug, Clone, Copy)]
pub struct CuWorkload {
    /// Input channels accumulated (I_C loop trips).
    pub c_in: usize,
    /// Weight taps per input channel (K²).
    pub taps: usize,
    /// Output positions per tap within the tile (`⌈T/S⌉²` for interior
    /// tiles; smaller at the fringe).
    pub macs_per_tap: usize,
    /// Output tile elements (bias init + final stream-out).
    pub tile_elems: usize,
}

impl CuWorkload {
    /// The interior-tile workload of one [`BlockSchedule`] micro-tile —
    /// the *same struct* the CPU kernels execute, so the cycle model and
    /// the software blocking sweep one tile geometry.  `macs_per_tap` is
    /// the `⌈T/S⌉²` output positions one weight tap touches;
    /// `tile_elems` is the `T²` micro-tile.
    pub fn from_block_schedule(
        sched: &BlockSchedule,
        c_in: usize,
        k: usize,
        stride: usize,
    ) -> Self {
        let t = sched.micro.max(1);
        let s = stride.max(1);
        CuWorkload {
            c_in,
            taps: k * k,
            macs_per_tap: t.div_ceil(s) * t.div_ceil(s),
            tile_elems: t * t,
        }
    }
}

/// CU timing parameters derived from the board.
#[derive(Debug, Clone, Copy)]
pub struct CuModel {
    /// Parallel MAC lanes per CU (DSP48s doing multiply-accumulate).
    pub lanes: usize,
    /// Pipeline fill overhead per workload (loop prologue, cycles).
    pub workload_overhead: u64,
    /// Per-input-channel overhead (BRAM block swap, cycles).
    pub per_channel_overhead: u64,
}

impl CuModel {
    pub fn from_board(board: &FpgaBoard) -> Self {
        Self::from_board_at(board, Precision::F32)
    }

    /// CU model at an explicit datapath precision: narrow fixed point
    /// packs more MAC lanes onto the same DSP budget.
    pub fn from_board_at(board: &FpgaBoard, precision: Precision) -> Self {
        CuModel {
            lanes: board.macs_per_cu_cycle * precision.lane_factor(),
            workload_overhead: 12,
            per_channel_overhead: 4,
        }
    }

    /// Cycles for one dense (no skipping) workload.
    pub fn dense_cycles(&self, w: &CuWorkload) -> u64 {
        let init = (w.tile_elems as u64).div_ceil(self.lanes as u64);
        let per_tap = (w.macs_per_tap as u64).div_ceil(self.lanes as u64);
        self.workload_overhead
            + init
            + w.c_in as u64
                * (self.per_channel_overhead
                    + w.taps as u64 * per_tap)
    }

    /// Cycles with zero-skipping: a fraction `zero_frac` of weight taps is
    /// zero and costs one test cycle instead of its MACs.  (Taps are
    /// weight-scalar granular, matching the per-`(i_c, k_h, k_w)` test in
    /// the CU inner loop.)
    pub fn zero_skip_cycles(&self, w: &CuWorkload, zero_frac: f64) -> u64 {
        assert!((0.0..=1.0).contains(&zero_frac), "bad zero fraction");
        let init = (w.tile_elems as u64).div_ceil(self.lanes as u64);
        let per_tap = (w.macs_per_tap as u64).div_ceil(self.lanes as u64);
        let taps_total = (w.c_in * w.taps) as f64;
        let dense_taps = (taps_total * (1.0 - zero_frac)).round() as u64;
        let skipped_taps = taps_total as u64 - dense_taps;
        self.workload_overhead
            + init
            + w.c_in as u64 * self.per_channel_overhead
            + dense_taps * (per_tap + 1) // 1 test cycle + MACs
            + skipped_taps // test-only cycles
    }

    /// MACs issued by one dense workload.
    pub fn dense_macs(&self, w: &CuWorkload) -> u64 {
        (w.c_in * w.taps * w.macs_per_tap) as u64
    }

    /// Cycles for one workload under the given execution mode
    /// (`sparsity = None` → dense, `Some(z)` → zero-skipping at `z`).
    pub fn workload_cycles(
        &self,
        w: &CuWorkload,
        sparsity: Option<f64>,
    ) -> u64 {
        match sparsity {
            None => self.dense_cycles(w),
            Some(z) => self.zero_skip_cycles(w, z),
        }
    }
}

/// One SIMD tile-batch simulated by the replicated CU array.
#[derive(Debug, Clone)]
pub struct BatchSim {
    /// Cycles each active CU spent on its workload (index = CU slot).
    pub per_cu: Vec<u64>,
    /// Critical path: the batch advances at the slowest CU (SIMD
    /// broadcast — every CU in the batch shares the input stream).
    pub critical: u64,
    /// Active CUs over array width.
    pub occupancy: f64,
}

/// The replicated CU array (the paper's `n_cu` compute units).  Each CU
/// of a batch is simulated concurrently on the worker pool — the
/// software execution path mirrors the spatial parallelism of the
/// hardware instead of iterating the units in a loop.
#[derive(Debug, Clone, Copy)]
pub struct CuArray {
    pub model: CuModel,
    pub n_cu: usize,
}

impl CuArray {
    pub fn from_board(board: &FpgaBoard) -> Self {
        Self::from_board_at(board, Precision::F32)
    }

    pub fn from_board_at(board: &FpgaBoard, precision: Precision) -> Self {
        CuArray {
            model: CuModel::from_board_at(board, precision),
            n_cu: board.n_cu,
        }
    }

    /// Simulate one tile batch: `workloads[i]` runs on CU slot `i`
    /// (at most `n_cu` workloads per batch), all units concurrently.
    pub fn simulate_batch(
        &self,
        workloads: &[CuWorkload],
        sparsity: Option<f64>,
        pool: &WorkerPool,
    ) -> BatchSim {
        assert!(
            workloads.len() <= self.n_cu,
            "batch of {} workloads exceeds the {}-CU array",
            workloads.len(),
            self.n_cu
        );
        let per_cu =
            pool.map(workloads, |w| self.model.workload_cycles(w, sparsity));
        let critical = per_cu.iter().copied().max().unwrap_or(0);
        BatchSim {
            critical,
            occupancy: if self.n_cu == 0 {
                0.0
            } else {
                workloads.len() as f64 / self.n_cu as f64
            },
            per_cu,
        }
    }

    /// Simulate `count` copies of one (uniform) workload streamed
    /// through successive SIMD batches of the array — the whole-layer
    /// engine: all CU evaluations run in a *single* pool dispatch (one
    /// thread scope per layer, not one per batch), then chunks of
    /// `n_cu` fold to their critical path (the batch advances at its
    /// slowest CU; the last chunk is the partial batch).  Returns the
    /// per-batch critical paths.
    pub fn simulate_uniform_workloads(
        &self,
        wl: &CuWorkload,
        count: usize,
        sparsity: Option<f64>,
        pool: &WorkerPool,
    ) -> Vec<u64> {
        // Individual CU evaluations are tiny — adaptive chunking
        // (job 0's measured cost seeds the claim size) amortizes the
        // dispatch overhead without a hand-tuned chunk, matching the
        // reverse-loop tile dispatch.  Identical results for any chunk:
        // every workload still owns its slot.
        let per_workload = pool.map_indexed_auto(count, |_| {
            self.model.workload_cycles(wl, sparsity)
        });
        per_workload
            .chunks(self.n_cu.max(1))
            .map(|batch| batch.iter().copied().max().unwrap_or(0))
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::PYNQ_Z2;

    fn wl() -> CuWorkload {
        CuWorkload {
            c_in: 64,
            taps: 16,
            macs_per_tap: 36, // T=12, S=2 → 6×6
            tile_elems: 144,
        }
    }

    #[test]
    fn block_schedule_yields_the_paper_workload() {
        // T=12, S=2, K=4, 64 channels — exactly the canonical workload
        // the other tests pin, built from the shared schedule struct.
        let sched = BlockSchedule { micro: 12, macro_tiles: 4, lanes: 4 };
        let w = CuWorkload::from_block_schedule(&sched, 64, 4, 2);
        let pinned = wl();
        assert_eq!(w.c_in, pinned.c_in);
        assert_eq!(w.taps, pinned.taps);
        assert_eq!(w.macs_per_tap, pinned.macs_per_tap);
        assert_eq!(w.tile_elems, pinned.tile_elems);
        // degenerate schedules clamp instead of dividing by zero
        let z = BlockSchedule { micro: 0, macro_tiles: 1, lanes: 1 };
        let w0 = CuWorkload::from_block_schedule(&z, 1, 3, 0);
        assert_eq!(w0.tile_elems, 1);
        assert_eq!(w0.macs_per_tap, 1);
    }

    #[test]
    fn fixed16_packs_twice_the_lanes() {
        use crate::config::QFormat;
        let f32_cu = CuModel::from_board_at(&PYNQ_Z2, Precision::F32);
        let q16 = CuModel::from_board_at(
            &PYNQ_Z2,
            Precision::Fixed(QFormat::new(16, 8)),
        );
        let q32 = CuModel::from_board_at(
            &PYNQ_Z2,
            Precision::Fixed(QFormat::new(32, 16)),
        );
        assert_eq!(q16.lanes, 2 * f32_cu.lanes);
        assert_eq!(q32.lanes, f32_cu.lanes);
        let w = wl();
        assert!(q16.dense_cycles(&w) < f32_cu.dense_cycles(&w));
    }

    #[test]
    fn int8_packs_four_lanes_per_dsp() {
        use crate::config::QFormat;
        let f32_cu = CuModel::from_board_at(&PYNQ_Z2, Precision::F32);
        let q16 = CuModel::from_board_at(
            &PYNQ_Z2,
            Precision::Fixed(QFormat::new(16, 8)),
        );
        let q8 = CuModel::from_board_at(
            &PYNQ_Z2,
            Precision::Fixed(QFormat::new(8, 6)),
        );
        assert_eq!(q8.lanes, 4 * f32_cu.lanes, "×4 INT8 MACs per DSP");
        assert_eq!(q8.lanes, 2 * q16.lanes);
        let w = wl();
        assert!(q8.dense_cycles(&w) < q16.dense_cycles(&w));
        assert!(q8.dense_cycles(&w) < f32_cu.dense_cycles(&w));
    }

    #[test]
    fn dense_cycles_track_macs() {
        let cu = CuModel::from_board(&PYNQ_Z2);
        let w = wl();
        let cycles = cu.dense_cycles(&w);
        // 64 × 16 taps × ceil(36/8)=5 cycles = 5120 + overheads
        assert!(cycles >= 5120);
        assert!(cycles < 5120 + 1000);
    }

    #[test]
    fn full_skip_is_much_cheaper() {
        let cu = CuModel::from_board(&PYNQ_Z2);
        let w = wl();
        let dense = cu.zero_skip_cycles(&w, 0.0);
        let empty = cu.zero_skip_cycles(&w, 1.0);
        assert!(empty * 3 < dense, "dense={dense} empty={empty}");
    }

    #[test]
    fn skip_cycles_monotone_in_sparsity() {
        let cu = CuModel::from_board(&PYNQ_Z2);
        let w = wl();
        let mut prev = u64::MAX;
        for i in 0..=10 {
            let z = i as f64 / 10.0;
            let c = cu.zero_skip_cycles(&w, z);
            assert!(c <= prev, "not monotone at z={z}");
            prev = c;
        }
    }

    #[test]
    fn zero_skip_at_zero_close_to_dense_plus_tests() {
        let cu = CuModel::from_board(&PYNQ_Z2);
        let w = wl();
        let dense = cu.dense_cycles(&w);
        let skip0 = cu.zero_skip_cycles(&w, 0.0);
        // skipping machinery adds exactly one test cycle per tap
        assert_eq!(skip0, dense + (w.c_in * w.taps) as u64);
    }

    #[test]
    #[should_panic]
    fn invalid_sparsity_panics() {
        let cu = CuModel::from_board(&PYNQ_Z2);
        cu.zero_skip_cycles(&wl(), 1.5);
    }

    #[test]
    fn concurrent_array_matches_per_cu_model() {
        let arr = CuArray::from_board(&PYNQ_Z2);
        let cu = CuModel::from_board(&PYNQ_Z2);
        let batch: Vec<CuWorkload> = vec![wl(); 16];
        for (workers, sparsity) in
            [(1, None), (4, None), (4, Some(0.5)), (8, Some(0.9))]
        {
            let pool = WorkerPool::new(workers);
            let sim = arr.simulate_batch(&batch, sparsity, &pool);
            assert_eq!(sim.per_cu.len(), 16);
            let want = cu.workload_cycles(&wl(), sparsity);
            assert!(sim.per_cu.iter().all(|c| *c == want));
            assert_eq!(sim.critical, want);
            assert_eq!(sim.occupancy, 1.0);
        }
    }

    #[test]
    fn partial_batch_reports_starvation() {
        let arr = CuArray::from_board(&PYNQ_Z2);
        let pool = WorkerPool::new(2);
        let batch: Vec<CuWorkload> = vec![wl(); 9];
        let sim = arr.simulate_batch(&batch, None, &pool);
        assert!((sim.occupancy - 9.0 / 16.0).abs() < 1e-12);
        assert_eq!(sim.per_cu.len(), 9);
    }

    #[test]
    #[should_panic]
    fn oversubscribed_batch_panics() {
        let arr = CuArray::from_board(&PYNQ_Z2);
        let pool = WorkerPool::new(1);
        let batch: Vec<CuWorkload> = vec![wl(); 17];
        arr.simulate_batch(&batch, None, &pool);
    }

    #[test]
    fn uniform_stream_folds_to_per_batch_criticals() {
        let arr = CuArray::from_board(&PYNQ_Z2);
        let pool = WorkerPool::new(4);
        // 35 workloads over a 16-CU array → 3 batches (16, 16, 3)
        let criticals = arr.simulate_uniform_workloads(&wl(), 35, None, &pool);
        assert_eq!(criticals.len(), 3);
        let want = arr.model.workload_cycles(&wl(), None);
        assert!(criticals.iter().all(|c| *c == want));
        // agrees with the general per-batch primitive
        let batch: Vec<CuWorkload> = vec![wl(); 3];
        assert_eq!(
            arr.simulate_batch(&batch, None, &pool).critical,
            criticals[2]
        );
        assert!(arr
            .simulate_uniform_workloads(&wl(), 0, None, &pool)
            .is_empty());
    }
}
