//! Compute-unit cycle model — stage (2) of the pipeline.
//!
//! Each CU executes Algorithm 1 for one `(output tile, output channel)`
//! workload: loop over input channels, then the weight taps (weight-
//! stationary, enhancement 2), issuing `(T/S)²` MACs per tap across its
//! DSP lanes.  Zero-skipping replaces a tap's MACs with a single weight
//! test cycle (the conditional-execution paradigm of Section V-C).

use crate::config::FpgaBoard;

/// One CU workload: a `T_OH × T_OW` output block for one output channel.
#[derive(Debug, Clone, Copy)]
pub struct CuWorkload {
    /// Input channels accumulated (I_C loop trips).
    pub c_in: usize,
    /// Weight taps per input channel (K²).
    pub taps: usize,
    /// Output positions per tap within the tile (`⌈T/S⌉²` for interior
    /// tiles; smaller at the fringe).
    pub macs_per_tap: usize,
    /// Output tile elements (bias init + final stream-out).
    pub tile_elems: usize,
}

/// CU timing parameters derived from the board.
#[derive(Debug, Clone, Copy)]
pub struct CuModel {
    /// Parallel MAC lanes per CU (DSP48s doing multiply-accumulate).
    pub lanes: usize,
    /// Pipeline fill overhead per workload (loop prologue, cycles).
    pub workload_overhead: u64,
    /// Per-input-channel overhead (BRAM block swap, cycles).
    pub per_channel_overhead: u64,
}

impl CuModel {
    pub fn from_board(board: &FpgaBoard) -> Self {
        CuModel {
            lanes: board.macs_per_cu_cycle,
            workload_overhead: 12,
            per_channel_overhead: 4,
        }
    }

    /// Cycles for one dense (no skipping) workload.
    pub fn dense_cycles(&self, w: &CuWorkload) -> u64 {
        let init = (w.tile_elems as u64).div_ceil(self.lanes as u64);
        let per_tap = (w.macs_per_tap as u64).div_ceil(self.lanes as u64);
        self.workload_overhead
            + init
            + w.c_in as u64
                * (self.per_channel_overhead
                    + w.taps as u64 * per_tap)
    }

    /// Cycles with zero-skipping: a fraction `zero_frac` of weight taps is
    /// zero and costs one test cycle instead of its MACs.  (Taps are
    /// weight-scalar granular, matching the per-`(i_c, k_h, k_w)` test in
    /// the CU inner loop.)
    pub fn zero_skip_cycles(&self, w: &CuWorkload, zero_frac: f64) -> u64 {
        assert!((0.0..=1.0).contains(&zero_frac), "bad zero fraction");
        let init = (w.tile_elems as u64).div_ceil(self.lanes as u64);
        let per_tap = (w.macs_per_tap as u64).div_ceil(self.lanes as u64);
        let taps_total = (w.c_in * w.taps) as f64;
        let dense_taps = (taps_total * (1.0 - zero_frac)).round() as u64;
        let skipped_taps = taps_total as u64 - dense_taps;
        self.workload_overhead
            + init
            + w.c_in as u64 * self.per_channel_overhead
            + dense_taps * (per_tap + 1) // 1 test cycle + MACs
            + skipped_taps // test-only cycles
    }

    /// MACs issued by one dense workload.
    pub fn dense_macs(&self, w: &CuWorkload) -> u64 {
        (w.c_in * w.taps * w.macs_per_tap) as u64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::PYNQ_Z2;

    fn wl() -> CuWorkload {
        CuWorkload {
            c_in: 64,
            taps: 16,
            macs_per_tap: 36, // T=12, S=2 → 6×6
            tile_elems: 144,
        }
    }

    #[test]
    fn dense_cycles_track_macs() {
        let cu = CuModel::from_board(&PYNQ_Z2);
        let w = wl();
        let cycles = cu.dense_cycles(&w);
        // 64 × 16 taps × ceil(36/8)=5 cycles = 5120 + overheads
        assert!(cycles >= 5120);
        assert!(cycles < 5120 + 1000);
    }

    #[test]
    fn full_skip_is_much_cheaper() {
        let cu = CuModel::from_board(&PYNQ_Z2);
        let w = wl();
        let dense = cu.zero_skip_cycles(&w, 0.0);
        let empty = cu.zero_skip_cycles(&w, 1.0);
        assert!(empty * 3 < dense, "dense={dense} empty={empty}");
    }

    #[test]
    fn skip_cycles_monotone_in_sparsity() {
        let cu = CuModel::from_board(&PYNQ_Z2);
        let w = wl();
        let mut prev = u64::MAX;
        for i in 0..=10 {
            let z = i as f64 / 10.0;
            let c = cu.zero_skip_cycles(&w, z);
            assert!(c <= prev, "not monotone at z={z}");
            prev = c;
        }
    }

    #[test]
    fn zero_skip_at_zero_close_to_dense_plus_tests() {
        let cu = CuModel::from_board(&PYNQ_Z2);
        let w = wl();
        let dense = cu.dense_cycles(&w);
        let skip0 = cu.zero_skip_cycles(&w, 0.0);
        // skipping machinery adds exactly one test cycle per tap
        assert_eq!(skip0, dense + (w.c_in * w.taps) as u64);
    }

    #[test]
    #[should_panic]
    fn invalid_sparsity_panics() {
        let cu = CuModel::from_board(&PYNQ_Z2);
        cu.zero_skip_cycles(&wl(), 1.5);
    }
}
