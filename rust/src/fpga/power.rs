//! FPGA power model.  The PYNQ-Z2 draws a near-constant board power: a
//! static floor (PS + idle PL) plus dynamic power proportional to switch
//! activity (DSP toggling, BRAM ports, AXI traffic).  The paper measures
//! this with a USB power meter; we integrate the same quantity from the
//! simulated activity factors.

use crate::config::FpgaBoard;

#[derive(Debug, Clone, Copy)]
pub struct PowerModel {
    static_w: f64,
    dynamic_w: f64,
}

impl PowerModel {
    pub fn from_board(board: &FpgaBoard) -> Self {
        PowerModel {
            static_w: board.static_power_w,
            dynamic_w: board.dynamic_power_w,
        }
    }

    /// Average power for a layer: the CU array toggles at
    /// `occupancy × compute_duty`, memory machinery at a fixed share.
    ///
    /// * `occupancy` — fraction of CUs with work (C_out starvation).
    /// * `compute_duty` — fraction of cycles the compute stage is active.
    pub fn layer_power(&self, occupancy: f64, compute_duty: f64) -> f64 {
        debug_assert!((0.0..=1.0).contains(&occupancy));
        let duty = compute_duty.clamp(0.0, 1.0);
        // 70% of dynamic power is the CU/DSP array, 30% memory movement
        self.static_w + self.dynamic_w * (0.7 * occupancy * duty + 0.3)
    }

    /// Idle board power.
    pub fn idle(&self) -> f64 {
        self.static_w
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::PYNQ_Z2;

    #[test]
    fn power_bounded_by_board_limits() {
        let pm = PowerModel::from_board(&PYNQ_Z2);
        let full = pm.layer_power(1.0, 1.0);
        let idle = pm.layer_power(0.0, 0.0);
        assert!(full <= PYNQ_Z2.max_power_w() + 1e-9);
        assert!(idle >= PYNQ_Z2.static_power_w);
        assert!(full > idle);
    }

    #[test]
    fn starved_array_draws_less() {
        let pm = PowerModel::from_board(&PYNQ_Z2);
        assert!(pm.layer_power(3.0 / 16.0, 0.9) < pm.layer_power(1.0, 0.9));
    }
}
