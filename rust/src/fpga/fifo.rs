//! On-chip FIFO model — the streaming links between the AXI read blocks
//! and the CU array (Fig. 3).  Used by the pipeline model for stall
//! accounting and by tests as a plain bounded queue.

/// Bounded single-producer/single-consumer FIFO with occupancy stats.
#[derive(Debug, Clone)]
pub struct Fifo<T> {
    depth: usize,
    buf: std::collections::VecDeque<T>,
    /// Producer stalls observed (push attempted while full).
    pub stalls_full: u64,
    /// Consumer stalls observed (pop attempted while empty).
    pub stalls_empty: u64,
    /// High-water mark of occupancy.
    pub high_water: usize,
}

impl<T> Fifo<T> {
    pub fn new(depth: usize) -> Self {
        assert!(depth > 0, "FIFO depth must be positive");
        Fifo {
            depth,
            buf: std::collections::VecDeque::with_capacity(depth),
            stalls_full: 0,
            stalls_empty: 0,
            high_water: 0,
        }
    }

    pub fn depth(&self) -> usize {
        self.depth
    }

    pub fn len(&self) -> usize {
        self.buf.len()
    }

    pub fn is_empty(&self) -> bool {
        self.buf.is_empty()
    }

    pub fn is_full(&self) -> bool {
        self.buf.len() == self.depth
    }

    /// Try to push; records a stall and returns the item back when full.
    pub fn push(&mut self, item: T) -> Result<(), T> {
        if self.is_full() {
            self.stalls_full += 1;
            return Err(item);
        }
        self.buf.push_back(item);
        self.high_water = self.high_water.max(self.buf.len());
        Ok(())
    }

    /// Try to pop; records a stall when empty.
    pub fn pop(&mut self) -> Option<T> {
        match self.buf.pop_front() {
            Some(v) => Some(v),
            None => {
                self.stalls_empty += 1;
                None
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fifo_order_and_bounds() {
        let mut f = Fifo::new(2);
        assert!(f.push(1).is_ok());
        assert!(f.push(2).is_ok());
        assert!(f.push(3).is_err());
        assert_eq!(f.stalls_full, 1);
        assert_eq!(f.pop(), Some(1));
        assert_eq!(f.pop(), Some(2));
        assert_eq!(f.pop(), None);
        assert_eq!(f.stalls_empty, 1);
    }

    #[test]
    fn high_water_tracks_peak() {
        let mut f = Fifo::new(4);
        for i in 0..3 {
            f.push(i).unwrap();
        }
        f.pop();
        f.pop();
        assert_eq!(f.high_water, 3);
    }

    #[test]
    #[should_panic]
    fn zero_depth_rejected() {
        let _ = Fifo::<u8>::new(0);
    }
}
