//! Cycle-level simulator of the paper's FPGA accelerator (Section IV).
//!
//! The accelerator is a SIMD array of replicated compute units fed by a
//! three-stage pipeline: (1) dedicated AXI read blocks stream input and
//! weight tiles from DDR into on-chip FIFOs/BRAM, (2) the CU array
//! executes Algorithm 1 workloads over `T_OH × T_OW` output blocks, and
//! (3) a write block streams finished blocks back to DDR one-shot.
//!
//! The simulator counts cycles per stage from the same [`crate::deconv`]
//! op accounting the numeric substrate emits, overlaps the stages the way
//! the pipelined hardware does (limited by the slowest stage, plus
//! fill/drain), applies the resource model for Table I / DSE legality,
//! and integrates the power model for the GOps/s/W denominators.

mod axi;
mod cu;
mod fifo;
mod pipeline;
mod power;
mod resources;

pub use axi::AxiModel;
pub use cu::{BatchSim, CuArray, CuModel, CuWorkload};
pub use fifo::Fifo;
pub use pipeline::{
    measured_account, measured_run, measurement_rng, simulate_layer,
    simulate_layer_par, simulate_network, simulate_network_par, LayerSim,
    NetworkSim, SimOpts,
};
pub use power::PowerModel;
pub use resources::{estimate_resources, estimate_resources_at, Utilization};
