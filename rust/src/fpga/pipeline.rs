//! Three-stage pipeline model of the accelerator (Fig. 3) and the
//! per-layer / per-network simulation entry points the experiments use.
//!
//! Per layer, the output space is tiled into `⌈O/T⌉²` spatial tiles; each
//! tile is processed by batches of up to `n_cu` output channels (the SIMD
//! broadcast: all CUs in a batch share the input block stream).  For each
//! *tile batch* the model computes
//!
//! * `read`   — AXI cycles for the input block (broadcast once) and the
//!   per-CU weight blocks (enhancement 3 makes these sequential bursts),
//! * `compute`— CU cycles for the Algorithm 1 workload (with optional
//!   zero-skipping at the layer's measured weight sparsity),
//! * `write`  — AXI cycles for the one-shot output block write-back,
//!
//! and schedules the batches through the pipeline: with decoupled access
//! (the default) the stages overlap and the batch advances at the pace of
//! its slowest stage; the ablation `decouple = false` serializes them and
//! pays the random-access penalty on input reads, quantifying
//! enhancements (2)+(3).

use super::axi::AxiModel;
use super::cu::{CuModel, CuWorkload};
use super::power::PowerModel;
use crate::config::{DeconvLayerCfg, FpgaBoard, NetworkCfg};
use crate::deconv::input_tile_extent;
use crate::util::Rng;

/// Options for a layer simulation.
#[derive(Debug, Clone, Copy)]
pub struct SimOpts {
    /// Output tiling factor `T_OH = T_OW` (unified per network, Table I).
    pub tile: usize,
    /// Zero-skipping enabled (Section V-C) at this weight sparsity.
    pub zero_skip: bool,
    /// Fraction of exactly-zero weights in the layer.
    pub weight_sparsity: f64,
    /// Decoupled external memory access (enhancement 3). `false` is the
    /// ablation: serialized stages + random-access input reads.
    pub decouple: bool,
}

impl SimOpts {
    pub fn dense(tile: usize) -> Self {
        SimOpts {
            tile,
            zero_skip: false,
            weight_sparsity: 0.0,
            decouple: true,
        }
    }
}

/// Result of simulating one layer.
#[derive(Debug, Clone, Copy)]
pub struct LayerSim {
    /// Arithmetic operations (2 × MACs of the dense schedule — the
    /// paper's throughput numerator counts the layer workload, not the
    /// skipped subset).
    pub ops: u64,
    /// Total accelerator cycles.
    pub cycles: u64,
    /// Wall time at the board clock, seconds.
    pub time_s: f64,
    /// Throughput, GOps/s.
    pub gops: f64,
    /// Average power during the layer, watts.
    pub power_w: f64,
    /// The paper's Table II metric.
    pub gops_per_w: f64,
    /// Cycle breakdown.
    pub read_cycles: u64,
    pub compute_cycles: u64,
    pub write_cycles: u64,
    /// Mean CU occupancy in (0, 1] (C_out < n_cu starves the array —
    /// the CelebA L5 effect).
    pub occupancy: f64,
}

/// Result of simulating a whole network (the paper's "Total" column:
/// total ops / total time).
#[derive(Debug, Clone)]
pub struct NetworkSim {
    pub layers: Vec<LayerSim>,
    pub total_ops: u64,
    pub total_time_s: f64,
    pub total_gops: f64,
    pub mean_power_w: f64,
    pub gops_per_w: f64,
}

/// Simulate one deconvolution layer on the accelerator.
pub fn simulate_layer(
    layer: &DeconvLayerCfg,
    board: &FpgaBoard,
    opts: &SimOpts,
) -> LayerSim {
    let axi = AxiModel::from_board(board);
    let cu = CuModel::from_board(board);
    let o = layer.o_h();
    let t = opts.tile.min(o).max(1);
    let t_i = input_tile_extent(t, layer.k, layer.stride);
    let tiles_axis = o.div_ceil(t);
    let n_tiles = tiles_axis * tiles_axis;

    // One CU workload = one (spatial tile, output channel) pair — the CU
    // array exploits *both* parallelism axes, so low-channel layers
    // still fill the array with spatial tiles (and vice versa).
    let workloads = n_tiles * layer.c_out;
    let batches = workloads.div_ceil(board.n_cu) as u64;
    let occupancy = workloads as f64 / (batches * board.n_cu as u64) as f64;

    // Workload of an interior tile; fringe tiles are smaller but we
    // charge uniformly (the hardware issues the full tile and masks).
    let macs_per_tap = (t.div_ceil(layer.stride)).pow(2);
    let wl = CuWorkload {
        c_in: layer.c_in,
        taps: layer.k * layer.k,
        macs_per_tap,
        tile_elems: t * t,
    };
    let compute_per_batch = if opts.zero_skip {
        cu.zero_skip_cycles(&wl, opts.weight_sparsity)
    } else {
        cu.dense_cycles(&wl)
    };

    // Stage (1): distinct input blocks per batch (broadcast across the
    // CUs sharing a tile) + weight blocks for the batch's channels.
    // Zero-skipping streams pruned weights in a compressed (CSR-style)
    // layout: nnz values + indices (~1.25 B overhead per survivor).
    let channels_per_batch = layer.c_out.min(board.n_cu);
    let tiles_per_batch =
        (board.n_cu.div_ceil(channels_per_batch)).clamp(1, n_tiles);
    let input_bytes =
        4 * (layer.c_in * t_i * t_i) as u64 * tiles_per_batch as u64;
    let dense_weight_bytes =
        4 * (layer.c_in * layer.k * layer.k) as u64 * channels_per_batch as u64;
    let weight_bytes = if opts.zero_skip {
        let survivors = 1.0 - opts.weight_sparsity;
        ((dense_weight_bytes as f64 * survivors * 1.25) as u64)
            .min(dense_weight_bytes)
    } else {
        dense_weight_bytes
    };
    let read_per_batch = if opts.decouple {
        axi.sequential_cycles(input_bytes + weight_bytes)
    } else {
        // ablation: Eq. 4's scattered input addresses hit DDR directly
        axi.random_cycles(input_bytes) + axi.sequential_cycles(weight_bytes)
    };

    // Stage (3): one-shot output block write per active CU.
    let active = (workloads as u64).min(board.n_cu as u64);
    let write_per_batch = axi.sequential_cycles(4 * (t * t) as u64 * active);

    let total_cycles = if opts.decouple {
        // pipelined: steady-state advance at the slowest stage
        let stage_max = read_per_batch
            .max(compute_per_batch)
            .max(write_per_batch);
        read_per_batch + stage_max * batches + write_per_batch
    } else {
        (read_per_batch + compute_per_batch + write_per_batch) * batches
    };

    let time_s = total_cycles as f64 / board.clock_hz;
    let ops = layer.ops();
    let power = PowerModel::from_board(board).layer_power(
        occupancy,
        compute_per_batch as f64 * batches as f64 / total_cycles as f64,
    );
    let gops = ops as f64 / time_s / 1e9;
    LayerSim {
        ops,
        cycles: total_cycles,
        time_s,
        gops,
        power_w: power,
        gops_per_w: gops / power,
        read_cycles: read_per_batch * batches,
        compute_cycles: compute_per_batch * batches,
        write_cycles: write_per_batch * batches,
        occupancy,
    }
}

/// Simulate a whole network (layers multiplexed through the one
/// accelerator, as the paper's design does).
pub fn simulate_network(
    net: &NetworkCfg,
    board: &FpgaBoard,
    opts_per_layer: &[SimOpts],
) -> NetworkSim {
    assert_eq!(opts_per_layer.len(), net.layers.len());
    let layers: Vec<LayerSim> = net
        .layers
        .iter()
        .zip(opts_per_layer)
        .map(|(l, o)| simulate_layer(l, board, o))
        .collect();
    let total_ops: u64 = layers.iter().map(|l| l.ops).sum();
    let total_time_s: f64 = layers.iter().map(|l| l.time_s).sum();
    let energy: f64 = layers.iter().map(|l| l.power_w * l.time_s).sum();
    let mean_power = energy / total_time_s;
    let total_gops = total_ops as f64 / total_time_s / 1e9;
    NetworkSim {
        layers,
        total_ops,
        total_time_s,
        total_gops,
        mean_power_w: mean_power,
        gops_per_w: total_gops / mean_power,
    }
}

/// One measured "run" with realistic FPGA run-to-run variation: the
/// dataflow is deterministic, so only clock/DDR-refresh jitter remains
/// (σ/μ ≈ 0.3%, the workload-insensitive behaviour the paper leans on).
pub fn measured_run(base: &LayerSim, rng: &mut Rng) -> LayerSim {
    let jitter: f64 = 1.0 + rng.range_f64(-0.006, 0.006);
    let time = base.time_s * jitter;
    let power = base.power_w * (1.0 + rng.range_f64(-0.004, 0.004));
    let gops = base.ops as f64 / time / 1e9;
    LayerSim {
        time_s: time,
        gops,
        power_w: power,
        gops_per_w: gops / power,
        ..*base
    }
}

/// Convenience: deterministic seeded RNG for measurement series.
pub fn measurement_rng(seed: u64) -> Rng {
    Rng::seed_from_u64(seed)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{celeba, mnist, PYNQ_Z2};

    #[test]
    fn mnist_layers_sane() {
        let net = mnist();
        let opts: Vec<SimOpts> =
            net.layers.iter().map(|_| SimOpts::dense(net.tile)).collect();
        let sim = simulate_network(&net, &PYNQ_Z2, &opts);
        assert_eq!(sim.layers.len(), 3);
        for l in &sim.layers {
            assert!(l.time_s > 0.0);
            assert!(l.gops > 0.0);
            assert!(l.gops < PYNQ_Z2.peak_gops(), "cannot exceed roofline");
            assert!(l.power_w > PYNQ_Z2.static_power_w);
            assert!(l.power_w <= PYNQ_Z2.max_power_w() + 1e-9);
        }
        // whole-network time is the sum of layers (multiplexed design)
        let sum: f64 = sim.layers.iter().map(|l| l.time_s).sum();
        assert!((sim.total_time_s - sum).abs() < 1e-12);
    }

    #[test]
    fn low_channel_layers_lose_occupancy() {
        // CelebA L5 (C_out = 3, 9 tiles at T=24) leaves CU slots idle:
        // 27 workloads over 2 batches of 16 → 27/32
        let net = celeba();
        let last = net.layers.last().unwrap();
        let sim = simulate_layer(last, &PYNQ_Z2, &SimOpts::dense(net.tile));
        assert!((sim.occupancy - 27.0 / 32.0).abs() < 1e-12);
        // MNIST L3 (C_out = 1, 9 tiles at T=12) → 9/16
        let m = mnist();
        let s3 = simulate_layer(
            m.layers.last().unwrap(),
            &PYNQ_Z2,
            &SimOpts::dense(m.tile),
        );
        assert!((s3.occupancy - 9.0 / 16.0).abs() < 1e-12);
        // wide layers fill the array completely
        let s1 = simulate_layer(&m.layers[0], &PYNQ_Z2, &SimOpts::dense(m.tile));
        assert_eq!(s1.occupancy, 1.0);
    }

    #[test]
    fn zero_skip_speeds_up_sparse_layers() {
        let net = mnist();
        let layer = &net.layers[1];
        let dense =
            simulate_layer(layer, &PYNQ_Z2, &SimOpts::dense(net.tile));
        let sparse = simulate_layer(
            layer,
            &PYNQ_Z2,
            &SimOpts {
                tile: net.tile,
                zero_skip: true,
                weight_sparsity: 0.8,
                decouple: true,
            },
        );
        assert!(sparse.time_s < dense.time_s);
    }

    #[test]
    fn decoupling_ablation_hurts() {
        let net = celeba();
        let layer = &net.layers[2];
        let on = simulate_layer(layer, &PYNQ_Z2, &SimOpts::dense(net.tile));
        let off = simulate_layer(
            layer,
            &PYNQ_Z2,
            &SimOpts {
                decouple: false,
                ..SimOpts::dense(net.tile)
            },
        );
        assert!(
            off.time_s > on.time_s * 1.3,
            "serialized+random must be clearly slower: {} vs {}",
            off.time_s,
            on.time_s
        );
    }

    #[test]
    fn fpga_variation_is_tiny() {
        let net = mnist();
        let base =
            simulate_layer(&net.layers[0], &PYNQ_Z2, &SimOpts::dense(net.tile));
        let mut rng = measurement_rng(1);
        let runs: Vec<f64> = (0..50)
            .map(|_| measured_run(&base, &mut rng).gops_per_w)
            .collect();
        let s = crate::stats::Summary::of(&runs);
        assert!(s.std / s.mean < 0.01, "cv={}", s.std / s.mean);
    }
}
