//! Three-stage pipeline model of the accelerator (Fig. 3) and the
//! per-layer / per-network simulation entry points the experiments use.
//!
//! Per layer, the output space is tiled into `⌈O/T⌉²` spatial tiles; each
//! tile is processed by batches of up to `n_cu` output channels (the SIMD
//! broadcast: all CUs in a batch share the input block stream).  For each
//! *tile batch* the model computes
//!
//! * `read`   — AXI cycles for the input block (broadcast once) and the
//!   per-CU weight blocks (enhancement 3 makes these sequential bursts),
//! * `compute`— CU cycles for the Algorithm 1 workload (with optional
//!   zero-skipping at the layer's measured weight sparsity),
//! * `write`  — AXI cycles for the one-shot output block write-back,
//!
//! and schedules the batches through the pipeline: with decoupled access
//! (the default) the stages overlap and the batch advances at the pace of
//! its slowest stage; the ablation `decouple = false` serializes them and
//! pays the random-access penalty on input reads, quantifying
//! enhancements (2)+(3).
//!
//! Two equivalent compute engines drive the batches:
//! [`simulate_layer`] evaluates the (uniform) batch analytically, while
//! [`simulate_layer_par`] instantiates the full [`CuArray`] and runs each
//! batch's CUs *concurrently* on a [`WorkerPool`] — the software path
//! shaped like the hardware.  The two agree exactly (asserted in tests);
//! [`simulate_network_par`] additionally shards whole layers across the
//! pool.

use super::axi::AxiModel;
use super::cu::{CuArray, CuModel, CuWorkload};
use super::power::PowerModel;
use crate::config::{DeconvLayerCfg, FpgaBoard, NetworkCfg, Precision};
use crate::deconv::input_tile_extent;
use crate::util::{Rng, WorkerPool};

/// Options for a layer simulation.
#[derive(Debug, Clone, Copy)]
pub struct SimOpts {
    /// Output tiling factor `T_OH = T_OW` (unified per network, Table I).
    pub tile: usize,
    /// Zero-skipping enabled (Section V-C) at this weight sparsity.
    pub zero_skip: bool,
    /// Fraction of exactly-zero weights in the layer.
    pub weight_sparsity: f64,
    /// Decoupled external memory access (enhancement 3). `false` is the
    /// ablation: serialized stages + random-access input reads.
    pub decouple: bool,
    /// Datapath precision: scales AXI byte traffic (element width) and
    /// CU MAC lanes (DSP packing) — the fixed-point path the hardware
    /// actually runs.
    pub precision: Precision,
}

impl SimOpts {
    pub fn dense(tile: usize) -> Self {
        Self::dense_at(tile, Precision::F32)
    }

    /// Dense options at an explicit datapath precision.
    pub fn dense_at(tile: usize, precision: Precision) -> Self {
        SimOpts {
            tile,
            zero_skip: false,
            weight_sparsity: 0.0,
            decouple: true,
            precision,
        }
    }

    /// The CU execution mode this option set selects.
    fn sparsity_mode(&self) -> Option<f64> {
        if self.zero_skip {
            Some(self.weight_sparsity)
        } else {
            None
        }
    }
}

/// Result of simulating one layer.
#[derive(Debug, Clone, Copy)]
pub struct LayerSim {
    /// Arithmetic operations (2 × MACs of the dense schedule — the
    /// paper's throughput numerator counts the layer workload, not the
    /// skipped subset).
    pub ops: u64,
    /// Total accelerator cycles.
    pub cycles: u64,
    /// Wall time at the board clock, seconds.
    pub time_s: f64,
    /// Throughput, GOps/s.
    pub gops: f64,
    /// Average power during the layer, watts.
    pub power_w: f64,
    /// The paper's Table II metric.
    pub gops_per_w: f64,
    /// Cycle breakdown.
    pub read_cycles: u64,
    pub compute_cycles: u64,
    pub write_cycles: u64,
    /// Mean CU occupancy in (0, 1] (C_out < n_cu starves the array —
    /// the CelebA L5 effect).
    pub occupancy: f64,
}

/// Result of simulating a whole network (the paper's "Total" column:
/// total ops / total time).
#[derive(Debug, Clone)]
pub struct NetworkSim {
    pub layers: Vec<LayerSim>,
    pub total_ops: u64,
    pub total_time_s: f64,
    pub total_gops: f64,
    pub mean_power_w: f64,
    pub gops_per_w: f64,
}

/// Static per-layer schedule: tiling, CU batching and the read/write
/// stage costs — everything except the compute engine.
struct LayerSchedule {
    /// Total CU workloads (`tiles × c_out`).
    workloads: usize,
    /// SIMD tile batches (`⌈workloads / n_cu⌉`).
    batches: u64,
    occupancy: f64,
    /// The (uniform interior) workload each CU executes.
    wl: CuWorkload,
    read_per_batch: u64,
    write_per_batch: u64,
}

/// Derive the schedule of one layer at one option set (the top half of
/// the original `simulate_layer`, shared by both compute engines).
fn layer_schedule(
    layer: &DeconvLayerCfg,
    board: &FpgaBoard,
    opts: &SimOpts,
) -> LayerSchedule {
    let axi = AxiModel::from_board(board);
    let o = layer.o_h();
    let t = opts.tile.min(o).max(1);
    let t_i = input_tile_extent(t, layer.k, layer.stride);
    let tiles_axis = o.div_ceil(t);
    let n_tiles = tiles_axis * tiles_axis;

    // One CU workload = one (spatial tile, output channel) pair — the CU
    // array exploits *both* parallelism axes, so low-channel layers
    // still fill the array with spatial tiles (and vice versa).
    let workloads = n_tiles * layer.c_out;
    let batches = workloads.div_ceil(board.n_cu) as u64;
    let occupancy = workloads as f64 / (batches * board.n_cu as u64) as f64;

    // Workload of an interior tile; fringe tiles are smaller but we
    // charge uniformly (the hardware issues the full tile and masks).
    let macs_per_tap = (t.div_ceil(layer.stride)).pow(2);
    let wl = CuWorkload {
        c_in: layer.c_in,
        taps: layer.k * layer.k,
        macs_per_tap,
        tile_elems: t * t,
    };

    // Stage (1): distinct input blocks per batch (broadcast across the
    // CUs sharing a tile) + weight blocks for the batch's channels.
    // Zero-skipping streams pruned weights in a compressed (CSR-style)
    // layout: nnz values + indices (~1.25 B overhead per survivor).
    let eb = opts.precision.elem_bytes();
    let channels_per_batch = layer.c_out.min(board.n_cu);
    let tiles_per_batch =
        (board.n_cu.div_ceil(channels_per_batch)).clamp(1, n_tiles);
    let input_bytes =
        eb * (layer.c_in * t_i * t_i) as u64 * tiles_per_batch as u64;
    let dense_weight_bytes =
        eb * (layer.c_in * layer.k * layer.k) as u64 * channels_per_batch as u64;
    let weight_bytes = if opts.zero_skip {
        let survivors = 1.0 - opts.weight_sparsity;
        ((dense_weight_bytes as f64 * survivors * 1.25) as u64)
            .min(dense_weight_bytes)
    } else {
        dense_weight_bytes
    };
    let read_per_batch = if opts.decouple {
        axi.sequential_cycles(input_bytes + weight_bytes)
    } else {
        // ablation: Eq. 4's scattered input addresses hit DDR directly
        axi.random_cycles(input_bytes) + axi.sequential_cycles(weight_bytes)
    };

    // Stage (3): one-shot output block write per active CU.
    let active = (workloads as u64).min(board.n_cu as u64);
    let write_per_batch = axi.sequential_cycles(eb * (t * t) as u64 * active);

    LayerSchedule {
        workloads,
        batches,
        occupancy,
        wl,
        read_per_batch,
        write_per_batch,
    }
}

/// Fold per-batch compute cycles through the pipeline model into the
/// final [`LayerSim`].
fn assemble_layer_sim(
    layer: &DeconvLayerCfg,
    board: &FpgaBoard,
    opts: &SimOpts,
    sched: &LayerSchedule,
    compute_batches: &[u64],
) -> LayerSim {
    debug_assert_eq!(compute_batches.len() as u64, sched.batches);
    let compute_total: u64 = compute_batches.iter().sum();
    let total_cycles = if opts.decouple {
        // pipelined: each batch advances at its slowest stage
        let mut cycles = sched.read_per_batch + sched.write_per_batch;
        for &c in compute_batches {
            cycles += sched
                .read_per_batch
                .max(c)
                .max(sched.write_per_batch);
        }
        cycles
    } else {
        compute_total
            + (sched.read_per_batch + sched.write_per_batch) * sched.batches
    };

    let time_s = total_cycles as f64 / board.clock_hz;
    let ops = layer.ops();
    let power = PowerModel::from_board(board).layer_power(
        sched.occupancy,
        compute_total as f64 / total_cycles as f64,
    );
    let gops = ops as f64 / time_s / 1e9;
    LayerSim {
        ops,
        cycles: total_cycles,
        time_s,
        gops,
        power_w: power,
        gops_per_w: gops / power,
        read_cycles: sched.read_per_batch * sched.batches,
        compute_cycles: compute_total,
        write_cycles: sched.write_per_batch * sched.batches,
        occupancy: sched.occupancy,
    }
}

/// Simulate one deconvolution layer on the accelerator (analytical
/// compute engine: every batch is uniform, so one CU evaluation covers
/// the batch).
pub fn simulate_layer(
    layer: &DeconvLayerCfg,
    board: &FpgaBoard,
    opts: &SimOpts,
) -> LayerSim {
    let sched = layer_schedule(layer, board, opts);
    let cu = CuModel::from_board_at(board, opts.precision);
    let compute_per_batch =
        cu.workload_cycles(&sched.wl, opts.sparsity_mode());
    let compute_batches = vec![compute_per_batch; sched.batches as usize];
    assemble_layer_sim(layer, board, opts, &sched, &compute_batches)
}

/// Simulate one layer with the *concurrent* CU-array engine
/// ([`CuArray::simulate_uniform_workloads`]): every CU workload of
/// every tile batch runs on the worker pool in a single dispatch, and
/// each SIMD batch advances at its critical path — exactly what the
/// analytical path assumes, so the two agree cycle for cycle (asserted
/// in tests).
pub fn simulate_layer_par(
    layer: &DeconvLayerCfg,
    board: &FpgaBoard,
    opts: &SimOpts,
    pool: &WorkerPool,
) -> LayerSim {
    let sched = layer_schedule(layer, board, opts);
    let array = CuArray::from_board_at(board, opts.precision);
    let compute_batches = array.simulate_uniform_workloads(
        &sched.wl,
        sched.workloads,
        opts.sparsity_mode(),
        pool,
    );
    assemble_layer_sim(layer, board, opts, &sched, &compute_batches)
}

/// Shared network aggregation (the paper's "Total" row: layers are
/// multiplexed through the one accelerator, so times add).
fn aggregate_network(layers: Vec<LayerSim>) -> NetworkSim {
    let total_ops: u64 = layers.iter().map(|l| l.ops).sum();
    let total_time_s: f64 = layers.iter().map(|l| l.time_s).sum();
    let energy: f64 = layers.iter().map(|l| l.power_w * l.time_s).sum();
    let mean_power = energy / total_time_s;
    let total_gops = total_ops as f64 / total_time_s / 1e9;
    NetworkSim {
        layers,
        total_ops,
        total_time_s,
        total_gops,
        mean_power_w: mean_power,
        gops_per_w: total_gops / mean_power,
    }
}

/// Simulate a whole network (layers multiplexed through the one
/// accelerator, as the paper's design does).
pub fn simulate_network(
    net: &NetworkCfg,
    board: &FpgaBoard,
    opts_per_layer: &[SimOpts],
) -> NetworkSim {
    assert_eq!(opts_per_layer.len(), net.layers.len());
    let layers: Vec<LayerSim> = net
        .layers
        .iter()
        .zip(opts_per_layer)
        .map(|(l, o)| simulate_layer(l, board, o))
        .collect();
    aggregate_network(layers)
}

/// [`simulate_network`] with the layer simulations sharded across a
/// [`WorkerPool`] (temporal parallelism: independent layer models run
/// concurrently; aggregation stays in layer order, so the result is
/// bit-identical to the serial sweep).
pub fn simulate_network_par(
    net: &NetworkCfg,
    board: &FpgaBoard,
    opts_per_layer: &[SimOpts],
    pool: &WorkerPool,
) -> NetworkSim {
    assert_eq!(opts_per_layer.len(), net.layers.len());
    let layers = pool.map_indexed(net.layers.len(), |i| {
        simulate_layer(&net.layers[i], board, &opts_per_layer[i])
    });
    aggregate_network(layers)
}

/// One measured "run" with realistic FPGA run-to-run variation: the
/// dataflow is deterministic, so only clock/DDR-refresh jitter remains
/// (σ/μ ≈ 0.3%, the workload-insensitive behaviour the paper leans on).
pub fn measured_run(base: &LayerSim, rng: &mut Rng) -> LayerSim {
    let jitter: f64 = 1.0 + rng.range_f64(-0.006, 0.006);
    let time = base.time_s * jitter;
    let power = base.power_w * (1.0 + rng.range_f64(-0.004, 0.004));
    let gops = base.ops as f64 / time / 1e9;
    LayerSim {
        time_s: time,
        gops,
        power_w: power,
        gops_per_w: gops / power,
        ..*base
    }
}

/// Convenience: deterministic seeded RNG for measurement series.
pub fn measurement_rng(seed: u64) -> Rng {
    Rng::seed_from_u64(seed)
}

/// Apply the board's run-to-run measurement jitter to a whole-batch
/// `(time_s, energy_j)` account — the same clock/DDR-refresh σ as
/// [`measured_run`], for callers (the serving backend) that account at
/// batch granularity rather than per layer.
pub fn measured_account(time_s: f64, energy_j: f64, rng: &mut Rng) -> (f64, f64) {
    let t = time_s * (1.0 + rng.range_f64(-0.006, 0.006));
    let power = energy_j / time_s * (1.0 + rng.range_f64(-0.004, 0.004));
    (t, power * t)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{celeba, mnist, PYNQ_Z2};

    #[test]
    fn mnist_layers_sane() {
        let net = mnist();
        let opts: Vec<SimOpts> =
            net.layers.iter().map(|_| SimOpts::dense(net.tile)).collect();
        let sim = simulate_network(&net, &PYNQ_Z2, &opts);
        assert_eq!(sim.layers.len(), 3);
        for l in &sim.layers {
            assert!(l.time_s > 0.0);
            assert!(l.gops > 0.0);
            assert!(l.gops < PYNQ_Z2.peak_gops(), "cannot exceed roofline");
            assert!(l.power_w > PYNQ_Z2.static_power_w);
            assert!(l.power_w <= PYNQ_Z2.max_power_w() + 1e-9);
        }
        // whole-network time is the sum of layers (multiplexed design)
        let sum: f64 = sim.layers.iter().map(|l| l.time_s).sum();
        assert!((sim.total_time_s - sum).abs() < 1e-12);
    }

    #[test]
    fn low_channel_layers_lose_occupancy() {
        // CelebA L5 (C_out = 3, 9 tiles at T=24) leaves CU slots idle:
        // 27 workloads over 2 batches of 16 → 27/32
        let net = celeba();
        let last = net.layers.last().unwrap();
        let sim = simulate_layer(last, &PYNQ_Z2, &SimOpts::dense(net.tile));
        assert!((sim.occupancy - 27.0 / 32.0).abs() < 1e-12);
        // MNIST L3 (C_out = 1, 9 tiles at T=12) → 9/16
        let m = mnist();
        let s3 = simulate_layer(
            m.layers.last().unwrap(),
            &PYNQ_Z2,
            &SimOpts::dense(m.tile),
        );
        assert!((s3.occupancy - 9.0 / 16.0).abs() < 1e-12);
        // wide layers fill the array completely
        let s1 = simulate_layer(&m.layers[0], &PYNQ_Z2, &SimOpts::dense(m.tile));
        assert_eq!(s1.occupancy, 1.0);
    }

    #[test]
    fn zero_skip_speeds_up_sparse_layers() {
        let net = mnist();
        let layer = &net.layers[1];
        let dense =
            simulate_layer(layer, &PYNQ_Z2, &SimOpts::dense(net.tile));
        let sparse = simulate_layer(
            layer,
            &PYNQ_Z2,
            &SimOpts {
                zero_skip: true,
                weight_sparsity: 0.8,
                ..SimOpts::dense(net.tile)
            },
        );
        assert!(sparse.time_s < dense.time_s);
    }

    #[test]
    fn decoupling_ablation_hurts() {
        let net = celeba();
        let layer = &net.layers[2];
        let on = simulate_layer(layer, &PYNQ_Z2, &SimOpts::dense(net.tile));
        let off = simulate_layer(
            layer,
            &PYNQ_Z2,
            &SimOpts {
                decouple: false,
                ..SimOpts::dense(net.tile)
            },
        );
        assert!(
            off.time_s > on.time_s * 1.3,
            "serialized+random must be clearly slower: {} vs {}",
            off.time_s,
            on.time_s
        );
    }

    #[test]
    fn fpga_variation_is_tiny() {
        let net = mnist();
        let base =
            simulate_layer(&net.layers[0], &PYNQ_Z2, &SimOpts::dense(net.tile));
        let mut rng = measurement_rng(1);
        let runs: Vec<f64> = (0..50)
            .map(|_| measured_run(&base, &mut rng).gops_per_w)
            .collect();
        let s = crate::stats::Summary::of(&runs);
        assert!(s.std / s.mean < 0.01, "cv={}", s.std / s.mean);
    }

    fn layer_sims_equal(a: &LayerSim, b: &LayerSim) {
        assert_eq!(a.ops, b.ops);
        assert_eq!(a.cycles, b.cycles);
        assert_eq!(a.read_cycles, b.read_cycles);
        assert_eq!(a.compute_cycles, b.compute_cycles);
        assert_eq!(a.write_cycles, b.write_cycles);
        assert_eq!(a.time_s, b.time_s);
        assert_eq!(a.gops, b.gops);
        assert_eq!(a.power_w, b.power_w);
        assert_eq!(a.gops_per_w, b.gops_per_w);
        assert_eq!(a.occupancy, b.occupancy);
    }

    #[test]
    fn concurrent_cu_engine_matches_analytical() {
        for net in [mnist(), celeba()] {
            for layer in &net.layers {
                for opts in [
                    SimOpts::dense(net.tile),
                    SimOpts {
                        zero_skip: true,
                        weight_sparsity: 0.7,
                        ..SimOpts::dense(net.tile)
                    },
                    SimOpts {
                        decouple: false,
                        ..SimOpts::dense(net.tile)
                    },
                    SimOpts::dense_at(
                        net.tile,
                        Precision::Fixed(crate::config::QFormat::new(16, 8)),
                    ),
                ] {
                    let a = simulate_layer(layer, &PYNQ_Z2, &opts);
                    for workers in [1, 4] {
                        let pool = WorkerPool::new(workers);
                        let b =
                            simulate_layer_par(layer, &PYNQ_Z2, &opts, &pool);
                        layer_sims_equal(&a, &b);
                    }
                }
            }
        }
    }

    #[test]
    fn fixed_point_datapath_is_modeled() {
        use crate::config::QFormat;
        let q16 = Precision::Fixed(QFormat::new(16, 8));
        let q32 = Precision::Fixed(QFormat::new(32, 16));
        for net in [mnist(), celeba()] {
            for layer in &net.layers {
                let f = simulate_layer(layer, &PYNQ_Z2, &SimOpts::dense(net.tile));
                let s16 = simulate_layer(
                    layer,
                    &PYNQ_Z2,
                    &SimOpts::dense_at(net.tile, q16),
                );
                let s32 = simulate_layer(
                    layer,
                    &PYNQ_Z2,
                    &SimOpts::dense_at(net.tile, q32),
                );
                // 16-bit: half the AXI traffic, double the MAC lanes
                assert!(
                    s16.read_cycles <= f.read_cycles,
                    "16-bit reads must not exceed f32"
                );
                assert!(
                    s16.compute_cycles < f.compute_cycles,
                    "lane packing must speed up compute"
                );
                assert!(s16.time_s < f.time_s, "q8.8 must beat f32 end to end");
                // 32-bit fixed matches the f32 widths, so same schedule
                assert_eq!(s32.read_cycles, f.read_cycles);
                assert_eq!(s32.compute_cycles, f.compute_cycles);
                // the ops workload itself is precision-independent
                assert_eq!(s16.ops, f.ops);
                // 8-bit: 1-byte AXI words (no 2-byte floor) and ×4
                // packed MAC lanes — at or under q8.8 on both axes
                let s8 = simulate_layer(
                    layer,
                    &PYNQ_Z2,
                    &SimOpts::dense_at(
                        net.tile,
                        Precision::Fixed(QFormat::new(8, 6)),
                    ),
                );
                assert!(
                    s8.read_cycles <= s16.read_cycles,
                    "1-byte reads must not exceed 2-byte"
                );
                assert!(
                    s8.compute_cycles < s16.compute_cycles,
                    "×4 packing must beat ×2"
                );
                assert!(s8.time_s < f.time_s, "q8 must beat f32 end to end");
                assert_eq!(s8.ops, f.ops);
            }
        }
    }

    #[test]
    fn parallel_network_sweep_matches_serial() {
        for net in [mnist(), celeba()] {
            let opts: Vec<SimOpts> = net
                .layers
                .iter()
                .map(|_| SimOpts::dense(net.tile))
                .collect();
            let a = simulate_network(&net, &PYNQ_Z2, &opts);
            let pool = WorkerPool::new(4);
            let b = simulate_network_par(&net, &PYNQ_Z2, &opts, &pool);
            assert_eq!(a.total_ops, b.total_ops);
            assert_eq!(a.total_time_s, b.total_time_s);
            assert_eq!(a.total_gops, b.total_gops);
            assert_eq!(a.mean_power_w, b.mean_power_w);
            assert_eq!(a.gops_per_w, b.gops_per_w);
            for (la, lb) in a.layers.iter().zip(&b.layers) {
                layer_sims_equal(la, lb);
            }
        }
    }
}
