//! AXI/DDR external-memory model — stage (1) and (3) of the pipeline.
//!
//! The paper's enhancement (3) restricts external accesses to *sequential*
//! bursts (pixel addresses are precomputed, data is fetched in order and
//! cached in BRAM).  The model therefore charges: a fixed burst-setup
//! latency per transfer plus bytes/width cycles at the sustainable DDR
//! bandwidth, with a penalty multiplier for non-sequential access
//! patterns (used only by the ablation that disables enhancement 3).

use crate::config::FpgaBoard;

/// External memory channel model.
#[derive(Debug, Clone, Copy)]
pub struct AxiModel {
    /// Bytes transferred per PL cycle at the sustainable rate.
    pub bytes_per_cycle: f64,
    /// Fixed cycles to set up one burst transfer (address phase + DDR
    /// latency; ~30 PL cycles ≈ 240 ns at 125 MHz).
    pub burst_setup_cycles: u64,
    /// Maximum burst length in bytes (AXI4 256-beat × 8-byte beats).
    pub max_burst_bytes: u64,
    /// Throughput de-rating for non-sequential (random) accesses —
    /// row-activation thrash; DDR3 random ≈ 4-8× worse than streaming.
    pub random_penalty: f64,
}

impl AxiModel {
    /// Derive from a board description: sustainable bandwidth divided by
    /// the PL clock.
    pub fn from_board(board: &FpgaBoard) -> Self {
        AxiModel {
            bytes_per_cycle: board.stream_bw_bytes / board.clock_hz,
            burst_setup_cycles: 30,
            max_burst_bytes: 2048,
            random_penalty: 6.0,
        }
    }

    /// Cycles to move `bytes` sequentially (burst-decomposed).
    pub fn sequential_cycles(&self, bytes: u64) -> u64 {
        if bytes == 0 {
            return 0;
        }
        let bursts = bytes.div_ceil(self.max_burst_bytes);
        bursts * self.burst_setup_cycles
            + (bytes as f64 / self.bytes_per_cycle).ceil() as u64
    }

    /// Cycles to move `bytes` with a random access pattern (ablation of
    /// enhancement 3: every word pays setup + de-rated bandwidth).
    pub fn random_cycles(&self, bytes: u64) -> u64 {
        if bytes == 0 {
            return 0;
        }
        let words = bytes.div_ceil(4);
        words * 4 // one DDR transaction overhead amortized per word
            + (bytes as f64 * self.random_penalty / self.bytes_per_cycle)
                .ceil() as u64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::PYNQ_Z2;

    #[test]
    fn bandwidth_derivation() {
        let axi = AxiModel::from_board(&PYNQ_Z2);
        // 1.05 GB/s / 125 MHz = 8.4 B/cycle
        assert!((axi.bytes_per_cycle - 8.4).abs() < 1e-9);
    }

    #[test]
    fn zero_bytes_zero_cycles() {
        let axi = AxiModel::from_board(&PYNQ_Z2);
        assert_eq!(axi.sequential_cycles(0), 0);
        assert_eq!(axi.random_cycles(0), 0);
    }

    #[test]
    fn sequential_scales_linearly() {
        let axi = AxiModel::from_board(&PYNQ_Z2);
        let c1 = axi.sequential_cycles(4096);
        let c2 = axi.sequential_cycles(8192);
        assert!(c2 > c1);
        assert!(c2 < 3 * c1, "roughly linear");
    }

    #[test]
    fn random_much_slower_than_sequential() {
        let axi = AxiModel::from_board(&PYNQ_Z2);
        let bytes = 64 * 1024;
        assert!(axi.random_cycles(bytes) > 4 * axi.sequential_cycles(bytes));
    }
}
