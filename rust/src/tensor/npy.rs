//! Minimal NumPy `.npy` (format v1.0/2.0) reader/writer for dense C-order
//! arrays — the weight/ground-truth interchange with `python/compile`.
//!
//! Supports `<f4`/`<f8` on read (f8 converted to f32) and writes `<f4`
//! for the float contract, plus `<i2`/`<i4` for the quantized-weight
//! sidecar (`i2` widens losslessly to `i32` on read).  That is the
//! entire surface the artifact contract needs.

use anyhow::{bail, ensure, Context, Result};
use std::io::{Read, Write};
use std::path::Path;

const MAGIC: &[u8; 6] = b"\x93NUMPY";

/// Read an `.npy` file into `(shape, f32 data)`.
pub fn read_npy_f32(path: &Path) -> Result<(Vec<usize>, Vec<f32>)> {
    let (descr, shape, raw) = read_npy_raw(path)?;
    let numel: usize = shape.iter().product();
    let data = match descr.as_str() {
        "<f4" | "|f4" => {
            ensure!(raw.len() >= numel * 4, "npy payload too short");
            raw.chunks_exact(4)
                .take(numel)
                .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
                .collect()
        }
        "<f8" => {
            ensure!(raw.len() >= numel * 8, "npy payload too short");
            raw.chunks_exact(8)
                .take(numel)
                .map(|c| {
                    f64::from_le_bytes([
                        c[0], c[1], c[2], c[3], c[4], c[5], c[6], c[7],
                    ]) as f32
                })
                .collect()
        }
        other => bail!("unsupported npy dtype {other:?}"),
    };
    Ok((shape, data))
}

/// Write the `.npy` v1.0 preamble (magic + version + padded header) for
/// a dtype/shape and return the opened buffered writer.
fn open_npy_writer(
    path: &Path,
    shape: &[usize],
    descr: &str,
) -> Result<std::io::BufWriter<std::fs::File>> {
    let shape_str = match shape.len() {
        0 => "()".to_string(),
        1 => format!("({},)", shape[0]),
        _ => format!(
            "({})",
            shape
                .iter()
                .map(|d| d.to_string())
                .collect::<Vec<_>>()
                .join(", ")
        ),
    };
    let mut header = format!(
        "{{'descr': '{descr}', 'fortran_order': False, 'shape': {shape_str}, }}"
    );
    // pad so that magic(6)+ver(2)+len(2)+header is a multiple of 64
    let unpadded = 10 + header.len() + 1;
    let pad = (64 - unpadded % 64) % 64;
    header.push_str(&" ".repeat(pad));
    header.push('\n');

    let f = std::fs::File::create(path)
        .with_context(|| format!("creating {}", path.display()))?;
    let mut f = std::io::BufWriter::new(f);
    f.write_all(MAGIC)?;
    f.write_all(&[1u8, 0u8])?;
    f.write_all(&(header.len() as u16).to_le_bytes())?;
    f.write_all(header.as_bytes())?;
    Ok(f)
}

/// Write a dense C-order f32 array as `.npy` v1.0.
pub fn write_npy_f32(path: &Path, shape: &[usize], data: &[f32]) -> Result<()> {
    let numel: usize = shape.iter().product();
    ensure!(numel == data.len(), "shape/data mismatch");
    let mut f = open_npy_writer(path, shape, "<f4")?;
    for v in data {
        f.write_all(&v.to_le_bytes())?;
    }
    f.flush()?;
    Ok(())
}

/// Write a dense C-order i16 array as `.npy` v1.0 (`<i2`).
pub fn write_npy_i16(path: &Path, shape: &[usize], data: &[i16]) -> Result<()> {
    let numel: usize = shape.iter().product();
    ensure!(numel == data.len(), "shape/data mismatch");
    let mut f = open_npy_writer(path, shape, "<i2")?;
    for v in data {
        f.write_all(&v.to_le_bytes())?;
    }
    f.flush()?;
    Ok(())
}

/// Write a dense C-order i32 array as `.npy` v1.0 (`<i4`).
pub fn write_npy_i32(path: &Path, shape: &[usize], data: &[i32]) -> Result<()> {
    let numel: usize = shape.iter().product();
    ensure!(numel == data.len(), "shape/data mismatch");
    let mut f = open_npy_writer(path, shape, "<i4")?;
    for v in data {
        f.write_all(&v.to_le_bytes())?;
    }
    f.flush()?;
    Ok(())
}

/// Read an integer `.npy` file into `(shape, i32 data)` — accepts `<i2`
/// (widened losslessly) and `<i4`, the quantized-weight dtypes.
pub fn read_npy_i32(path: &Path) -> Result<(Vec<usize>, Vec<i32>)> {
    let (descr, shape, raw) = read_npy_raw(path)?;
    let numel: usize = shape.iter().product();
    let data = match descr.as_str() {
        "<i2" | "|i2" => {
            ensure!(raw.len() >= numel * 2, "npy payload too short");
            raw.chunks_exact(2)
                .take(numel)
                .map(|c| i16::from_le_bytes([c[0], c[1]]) as i32)
                .collect()
        }
        "<i4" | "|i4" => {
            ensure!(raw.len() >= numel * 4, "npy payload too short");
            raw.chunks_exact(4)
                .take(numel)
                .map(|c| i32::from_le_bytes([c[0], c[1], c[2], c[3]]))
                .collect()
        }
        other => bail!("unsupported integer npy dtype {other:?}"),
    };
    Ok((shape, data))
}

/// Shared header/payload reader: returns `(descr, shape, raw bytes)`.
fn read_npy_raw(path: &Path) -> Result<(String, Vec<usize>, Vec<u8>)> {
    let mut f = std::fs::File::open(path)
        .with_context(|| format!("opening {}", path.display()))?;
    let mut magic = [0u8; 8];
    f.read_exact(&mut magic).context("reading npy magic")?;
    ensure!(&magic[..6] == MAGIC, "not an npy file: {}", path.display());
    let major = magic[6];
    let header_len = match major {
        1 => {
            let mut b = [0u8; 2];
            f.read_exact(&mut b)?;
            u16::from_le_bytes(b) as usize
        }
        2 | 3 => {
            let mut b = [0u8; 4];
            f.read_exact(&mut b)?;
            u32::from_le_bytes(b) as usize
        }
        v => bail!("unsupported npy version {v}"),
    };
    let mut header = vec![0u8; header_len];
    f.read_exact(&mut header)?;
    let header = String::from_utf8(header).context("npy header not utf8")?;
    let descr = dict_str_value(&header, "descr")?;
    let fortran = dict_raw_value(&header, "fortran_order")?;
    ensure!(
        fortran.trim() == "False",
        "fortran-order npy unsupported ({})",
        path.display()
    );
    let shape = parse_shape(&dict_raw_value(&header, "shape")?)?;
    let mut raw = Vec::new();
    f.read_to_end(&mut raw)?;
    Ok((descr, shape, raw))
}

/// Extract a quoted string value from the python-dict-literal header.
fn dict_str_value(header: &str, key: &str) -> Result<String> {
    let raw = dict_raw_value(header, key)?;
    let t = raw.trim().trim_matches(|c| c == '\'' || c == '"');
    Ok(t.to_string())
}

/// Extract the raw token after `'key':` up to the next top-level comma.
fn dict_raw_value(header: &str, key: &str) -> Result<String> {
    let pat = format!("'{key}':");
    let start = header
        .find(&pat)
        .ok_or_else(|| anyhow::anyhow!("npy header missing key {key}"))?;
    let rest = &header[start + pat.len()..];
    let mut depth = 0usize;
    let mut out = String::new();
    for ch in rest.chars() {
        match ch {
            '(' | '[' => {
                depth += 1;
                out.push(ch);
            }
            ')' | ']' => {
                if depth == 0 {
                    break;
                }
                depth -= 1;
                out.push(ch);
            }
            ',' if depth == 0 => break,
            '}' if depth == 0 => break,
            _ => out.push(ch),
        }
    }
    Ok(out.trim().to_string())
}

fn parse_shape(raw: &str) -> Result<Vec<usize>> {
    let inner = raw.trim().trim_start_matches('(').trim_end_matches(')');
    let mut out = Vec::new();
    for tok in inner.split(',') {
        let tok = tok.trim();
        if tok.is_empty() {
            continue;
        }
        out.push(tok.parse::<usize>().context("bad shape token")?);
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_various_ranks() {
        let dir = crate::util::TempDir::new().unwrap();
        for shape in [vec![7], vec![2, 3], vec![1, 2, 3, 4]] {
            let numel: usize = shape.iter().product();
            let data: Vec<f32> = (0..numel).map(|i| i as f32 * 1.25).collect();
            let path = dir.path().join("x.npy");
            write_npy_f32(&path, &shape, &data).unwrap();
            let (s, d) = read_npy_f32(&path).unwrap();
            assert_eq!(s, shape);
            assert_eq!(d, data);
        }
    }

    #[test]
    fn header_is_64_byte_aligned() {
        let dir = crate::util::TempDir::new().unwrap();
        let path = dir.path().join("a.npy");
        write_npy_f32(&path, &[3], &[1.0, 2.0, 3.0]).unwrap();
        let bytes = std::fs::read(&path).unwrap();
        let header_len = u16::from_le_bytes([bytes[8], bytes[9]]) as usize;
        assert_eq!((10 + header_len) % 64, 0);
    }

    #[test]
    fn int_roundtrips_and_widening() {
        let dir = crate::util::TempDir::new().unwrap();
        let p16 = dir.path().join("a16.npy");
        let v16: Vec<i16> = vec![-32768, -1, 0, 1, 32767, 123];
        write_npy_i16(&p16, &[2, 3], &v16).unwrap();
        let (s, d) = read_npy_i32(&p16).unwrap();
        assert_eq!(s, vec![2, 3]);
        assert_eq!(d, v16.iter().map(|v| *v as i32).collect::<Vec<_>>());

        let p32 = dir.path().join("a32.npy");
        let v32: Vec<i32> = vec![i32::MIN, -7, 0, 9, i32::MAX];
        write_npy_i32(&p32, &[5], &v32).unwrap();
        let (s, d) = read_npy_i32(&p32).unwrap();
        assert_eq!(s, vec![5]);
        assert_eq!(d, v32);

        // reading a float file as int errors cleanly
        let pf = dir.path().join("f.npy");
        write_npy_f32(&pf, &[2], &[1.0, 2.0]).unwrap();
        assert!(read_npy_i32(&pf).is_err());
    }

    #[test]
    fn rejects_garbage() {
        let dir = crate::util::TempDir::new().unwrap();
        let path = dir.path().join("bad.npy");
        std::fs::write(&path, b"not an npy").unwrap();
        assert!(read_npy_f32(&path).is_err());
    }

    #[test]
    fn parses_1d_tuple_shape() {
        assert_eq!(parse_shape("(5,)").unwrap(), vec![5]);
        assert_eq!(parse_shape("(2, 3)").unwrap(), vec![2, 3]);
        assert_eq!(parse_shape("()").unwrap(), Vec::<usize>::new());
    }
}
