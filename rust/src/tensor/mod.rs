//! Minimal NCHW tensor type + `.npy` interchange.
//!
//! [`TensorT<T>`] is generic over the element type ([`Element`]): the
//! deconvolution substrate and the FPGA-path numerics run it in `f32`
//! or in Qm.n fixed point ([`crate::quant::Fixed`]).  [`Tensor`] is the
//! historical concrete `f32` alias — `.npy` interchange and the float
//! diagnostics live on it, and every pre-quantization call site keeps
//! its exact meaning.

mod npy;

pub use npy::{
    read_npy_f32, read_npy_i32, write_npy_f32, write_npy_i16, write_npy_i32,
};

pub use crate::quant::Element;

use anyhow::{ensure, Result};

/// Dense row-major (C-order) tensor of rank ≤ 4, NCHW for rank 4,
/// generic over the element type.
#[derive(Debug, Clone, PartialEq)]
pub struct TensorT<T: Element> {
    shape: Vec<usize>,
    data: Vec<T>,
}

/// The default `f32` tensor (the historical concrete type).
pub type Tensor = TensorT<f32>;

impl<T: Element> TensorT<T> {
    pub fn new(shape: Vec<usize>, data: Vec<T>) -> Result<Self> {
        let numel: usize = shape.iter().product();
        ensure!(
            numel == data.len(),
            "shape {:?} (numel {}) != data len {}",
            shape,
            numel,
            data.len()
        );
        Ok(TensorT { shape, data })
    }

    pub fn zeros(shape: Vec<usize>) -> Self {
        let numel = shape.iter().product();
        TensorT {
            shape,
            data: vec![T::ZERO; numel],
        }
    }

    pub fn from_fn(shape: Vec<usize>, f: impl FnMut(usize) -> T) -> Self {
        let numel: usize = shape.iter().product();
        TensorT {
            shape,
            data: (0..numel).map(f).collect(),
        }
    }

    pub fn shape(&self) -> &[usize] {
        &self.shape
    }

    pub fn numel(&self) -> usize {
        self.data.len()
    }

    pub fn data(&self) -> &[T] {
        &self.data
    }

    pub fn data_mut(&mut self) -> &mut [T] {
        &mut self.data
    }

    pub fn into_data(self) -> Vec<T> {
        self.data
    }

    /// Flat index of `[n, c, h, w]` (rank-4 only).
    #[inline]
    pub fn idx4(&self, n: usize, c: usize, h: usize, w: usize) -> usize {
        debug_assert_eq!(self.shape.len(), 4);
        ((n * self.shape[1] + c) * self.shape[2] + h) * self.shape[3] + w
    }

    #[inline]
    pub fn get4(&self, n: usize, c: usize, h: usize, w: usize) -> T {
        self.data[self.idx4(n, c, h, w)]
    }

    #[inline]
    pub fn set4(&mut self, n: usize, c: usize, h: usize, w: usize, v: T) {
        let i = self.idx4(n, c, h, w);
        self.data[i] = v;
    }

    /// Reshape in place (numel must match).
    pub fn reshape(mut self, shape: Vec<usize>) -> Result<Self> {
        let numel: usize = shape.iter().product();
        ensure!(numel == self.data.len(), "reshape numel mismatch");
        self.shape = shape;
        Ok(self)
    }

    /// Fraction of exactly-zero elements (sparsity measurement).
    pub fn zero_fraction(&self) -> f64 {
        if self.data.is_empty() {
            return 0.0;
        }
        let zeros = self.data.iter().filter(|v| v.is_zero()).count();
        zeros as f64 / self.data.len() as f64
    }
}

/// A zero-copy view of `[n, C, H, W]` images inside a shared batch
/// allocation — the serving path's reply payload.
///
/// The executor generates one batch tensor per dispatch; before this
/// type existed every request's reply `memcpy`'d its row range into a
/// fresh [`Tensor`].  An `ImageBlock` instead holds an [`Arc`] to the
/// batch buffer plus an offset/length window, so splitting a batch into
/// per-request payloads is O(1) per request and a served image is never
/// copied after generation.  [`ImageBlock::shares_allocation`] makes
/// that property observable (the allocation-counting integration test
/// asserts same-batch responses alias one buffer).
///
/// The read surface mirrors the [`Tensor`] methods response consumers
/// used (`shape`/`numel`/`data`/`max_abs_diff`), so call sites are
/// unchanged; [`ImageBlock::to_tensor`] is the explicit opt-in copy for
/// callers that genuinely need an owned tensor.
#[derive(Debug, Clone)]
pub struct ImageBlock {
    buf: std::sync::Arc<Vec<f32>>,
    offset: usize,
    shape: Vec<usize>,
}

impl ImageBlock {
    /// Wrap a whole batch tensor (one `Arc` allocation, no data copy).
    pub fn from_tensor(t: Tensor) -> Self {
        let shape = t.shape().to_vec();
        ImageBlock {
            buf: std::sync::Arc::new(t.into_data()),
            offset: 0,
            shape,
        }
    }

    /// Zero-copy sub-view of `n_images` images starting at image
    /// `first` (axis 0) — shares the backing buffer.
    pub fn slice_images(&self, first: usize, n_images: usize) -> Self {
        assert!(!self.shape.is_empty(), "rank-0 image block");
        assert!(
            first + n_images <= self.shape[0],
            "slice [{first}, {}) out of {} images",
            first + n_images,
            self.shape[0]
        );
        let per_image: usize = self.shape[1..].iter().product();
        let mut shape = self.shape.clone();
        shape[0] = n_images;
        ImageBlock {
            buf: std::sync::Arc::clone(&self.buf),
            offset: self.offset + first * per_image,
            shape,
        }
    }

    pub fn shape(&self) -> &[usize] {
        &self.shape
    }

    pub fn numel(&self) -> usize {
        self.shape.iter().product()
    }

    pub fn data(&self) -> &[f32] {
        &self.buf[self.offset..self.offset + self.numel()]
    }

    /// Explicit copy out into an owned [`Tensor`].
    pub fn to_tensor(&self) -> Tensor {
        TensorT {
            shape: self.shape.clone(),
            data: self.data().to_vec(),
        }
    }

    /// Maximum absolute elementwise difference (test assertions).
    pub fn max_abs_diff(&self, other: &ImageBlock) -> f32 {
        assert_eq!(self.shape, other.shape, "shape mismatch in diff");
        self.data()
            .iter()
            .zip(other.data())
            .map(|(a, b)| (a - b).abs())
            .fold(0.0, f32::max)
    }

    /// Whether two blocks are windows of the same backing allocation —
    /// the zero-copy proof the serving tests assert.
    pub fn shares_allocation(&self, other: &ImageBlock) -> bool {
        std::sync::Arc::ptr_eq(&self.buf, &other.buf)
    }
}

impl PartialEq for ImageBlock {
    /// Value equality (shape + contents) — aliasing is deliberately
    /// not part of equality; use [`ImageBlock::shares_allocation`].
    fn eq(&self, other: &Self) -> bool {
        self.shape == other.shape && self.data() == other.data()
    }
}

/// `f32`-specific surface: float accumulation helpers, diagnostics and
/// the `.npy` interchange with the Python build layer.
impl TensorT<f32> {
    #[inline]
    pub fn add4(&mut self, n: usize, c: usize, h: usize, w: usize, v: f32) {
        let i = self.idx4(n, c, h, w);
        self.data[i] += v;
    }

    /// Maximum absolute elementwise difference (for test assertions).
    pub fn max_abs_diff(&self, other: &Tensor) -> f32 {
        assert_eq!(self.shape, other.shape, "shape mismatch in diff");
        self.data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0, f32::max)
    }

    pub fn read_npy(path: &std::path::Path) -> Result<Self> {
        let (shape, data) = read_npy_f32(path)?;
        TensorT::new(shape, data)
    }

    pub fn write_npy(&self, path: &std::path::Path) -> Result<()> {
        write_npy_f32(path, &self.shape, &self.data)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::Q8_8;

    #[test]
    fn new_validates_numel() {
        assert!(Tensor::new(vec![2, 3], vec![0.0; 6]).is_ok());
        assert!(Tensor::new(vec![2, 3], vec![0.0; 5]).is_err());
    }

    #[test]
    fn idx4_row_major() {
        let t = Tensor::from_fn(vec![2, 3, 4, 5], |i| i as f32);
        assert_eq!(t.get4(0, 0, 0, 0), 0.0);
        assert_eq!(t.get4(0, 0, 0, 1), 1.0);
        assert_eq!(t.get4(0, 0, 1, 0), 5.0);
        assert_eq!(t.get4(0, 1, 0, 0), 20.0);
        assert_eq!(t.get4(1, 0, 0, 0), 60.0);
        assert_eq!(t.get4(1, 2, 3, 4), 119.0);
    }

    #[test]
    fn zero_fraction_counts() {
        let t = Tensor::new(vec![4], vec![0.0, 1.0, 0.0, 2.0]).unwrap();
        assert_eq!(t.zero_fraction(), 0.5);
    }

    #[test]
    fn generic_tensor_over_fixed_point() {
        let t: TensorT<Q8_8> =
            TensorT::from_fn(vec![2, 2], |i| Q8_8::from_f32(i as f32 * 0.5));
        assert_eq!(t.numel(), 4);
        assert_eq!(t.data()[3].to_f32(), 1.5);
        assert_eq!(t.zero_fraction(), 0.25);
        let z: TensorT<Q8_8> = TensorT::zeros(vec![3]);
        assert!(z.data().iter().all(|v| v.is_zero()));
    }

    #[test]
    fn image_block_slices_are_zero_copy_views() {
        let t = Tensor::from_fn(vec![3, 2, 2, 2], |i| i as f32);
        let numel_per_image = 8;
        let block = ImageBlock::from_tensor(t.clone());
        assert_eq!(block.shape(), &[3, 2, 2, 2]);
        assert_eq!(block.numel(), 24);
        assert_eq!(block.data(), t.data());

        let a = block.slice_images(0, 1);
        let b = block.slice_images(1, 2);
        assert_eq!(a.shape(), &[1, 2, 2, 2]);
        assert_eq!(b.shape(), &[2, 2, 2, 2]);
        assert_eq!(a.data(), &t.data()[..numel_per_image]);
        assert_eq!(b.data(), &t.data()[numel_per_image..]);
        // the zero-copy property itself
        assert!(a.shares_allocation(&block));
        assert!(a.shares_allocation(&b));
        let copied = b.to_tensor();
        assert_eq!(copied.data(), b.data());
        let independent = ImageBlock::from_tensor(copied);
        assert!(!independent.shares_allocation(&b), "copy is a new buffer");
        assert_eq!(independent, b, "but value-equal");
    }

    #[test]
    #[should_panic(expected = "out of")]
    fn image_block_slice_bounds_checked() {
        let block = ImageBlock::from_tensor(Tensor::zeros(vec![2, 1, 1, 1]));
        let _ = block.slice_images(1, 2);
    }

    #[test]
    fn npy_roundtrip() {
        let dir = crate::util::TempDir::new().unwrap();
        let path = dir.path().join("t.npy");
        let t = Tensor::from_fn(vec![3, 2, 2, 1], |i| i as f32 * 0.5 - 1.0);
        t.write_npy(&path).unwrap();
        let back = Tensor::read_npy(&path).unwrap();
        assert_eq!(t, back);
    }
}
