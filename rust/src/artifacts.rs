//! Artifact-set access — the Rust side of the `make artifacts` contract
//! (see `python/compile/aot.py`, which writes the directory this module
//! reads):
//!
//! * `manifest.json` — shapes, batch buckets, artifact paths, op counts;
//! * `weights/<net>_l<i>_{w,b}.npy` — trained WGAN-GP weights;
//! * `<net>_truth.npy` — ground-truth sample batch for the Fig. 6 MMD;
//! * `<net>_gen_b<N>.hlo.txt`, `<net>_layer<i>_b1.hlo.txt` — AOT HLO
//!   text (consumed only by the `pjrt`-feature runtime).
//!
//! [`write_synthetic`] fabricates a weights+truth+manifest set (no HLO
//! text) from random draws, so the serving coordinator, Fig. 6 sweep and
//! the parallel-engine tests run end to end in environments where the
//! Python/JAX build layer never ran.  [`artifacts_or_skip`] deliberately
//! rejects such incomplete sets: the tests it guards assert properties of
//! *trained* artifacts.

use crate::config::{network_by_name, DeconvLayerCfg, NetworkCfg, Precision};
use crate::quant::{QFormat, QuantLayerRaw, QuantizedGenerator};
use crate::tensor::{
    read_npy_i32, write_npy_i16, write_npy_i32, Tensor,
};
use crate::util::{parse_json, Json, Rng};
use anyhow::{ensure, Context, Result};
use std::collections::BTreeMap;
use std::io::Write;
use std::path::{Path, PathBuf};

/// One network's manifest entry (the `networks.<name>` object).
#[derive(Debug, Clone)]
pub struct NetworkManifest {
    pub name: String,
    pub z_dim: usize,
    pub tile: usize,
    pub image_size: usize,
    pub image_channels: usize,
    /// Exported generator batch buckets, ascending.
    pub batch_sizes: Vec<usize>,
    /// Generator HLO file per bucket.
    pub generators: BTreeMap<usize, String>,
    /// Per-layer HLO files (batch 1).
    pub layer_artifacts: Vec<String>,
    /// Per-layer `(weights, bias)` npy files.
    pub weight_files: Vec<(String, String)>,
    /// Ground-truth sample batch npy.
    pub truth: String,
}

/// An opened artifact directory with its parsed manifest.
#[derive(Debug, Clone)]
pub struct ArtifactDir {
    pub root: PathBuf,
    manifest: Json,
}

impl ArtifactDir {
    /// Open `dir`, requiring a parseable `manifest.json`.
    pub fn open(dir: &Path) -> Result<Self> {
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {}", path.display()))?;
        let manifest = parse_json(&text)
            .with_context(|| format!("parsing {}", path.display()))?;
        let version = manifest.req("version")?.as_usize()?;
        ensure!(version == 1, "unsupported manifest version {version}");
        Ok(ArtifactDir {
            root: dir.to_path_buf(),
            manifest,
        })
    }

    /// Open the default location: `$EDGEDCNN_ARTIFACTS`, `./artifacts`,
    /// or `../artifacts` (the aot.py default relative to `rust/`).
    pub fn open_default() -> Result<Self> {
        let mut tried = Vec::new();
        for cand in default_candidates() {
            if cand.join("manifest.json").exists() {
                return Self::open(&cand);
            }
            tried.push(cand.display().to_string());
        }
        anyhow::bail!(
            "no artifact set found (tried: {}) — run `make artifacts` or \
             `edgedcnn synth`",
            tried.join(", ")
        )
    }

    fn net_json(&self, name: &str) -> Result<&Json> {
        self.manifest
            .req("networks")?
            .get(name)
            .ok_or_else(|| {
                anyhow::anyhow!("network {name:?} not in the manifest")
            })
    }

    /// Parse one network's manifest entry.
    pub fn network(&self, name: &str) -> Result<NetworkManifest> {
        let j = self.net_json(name)?;
        let batch_sizes: Vec<usize> = j
            .req("batch_sizes")?
            .as_arr()?
            .iter()
            .map(|b| b.as_usize())
            .collect::<Result<_>>()?;
        let mut generators = BTreeMap::new();
        for (k, v) in j.req("generators")?.as_obj()? {
            let bucket: usize = k
                .parse()
                .map_err(|_| anyhow::anyhow!("bad generator bucket {k:?}"))?;
            generators.insert(bucket, v.as_str()?.to_string());
        }
        let layer_artifacts: Vec<String> = j
            .req("layer_artifacts")?
            .as_arr()?
            .iter()
            .map(|a| Ok(a.as_str()?.to_string()))
            .collect::<Result<_>>()?;
        let weight_files: Vec<(String, String)> = j
            .req("weights")?
            .as_arr()?
            .iter()
            .map(|e| {
                Ok((
                    e.req("w")?.as_str()?.to_string(),
                    e.req("b")?.as_str()?.to_string(),
                ))
            })
            .collect::<Result<_>>()?;
        Ok(NetworkManifest {
            name: j.req("name")?.as_str()?.to_string(),
            z_dim: j.req("z_dim")?.as_usize()?,
            tile: j.req("tile")?.as_usize()?,
            image_size: j.req("image_size")?.as_usize()?,
            image_channels: j.req("image_channels")?.as_usize()?,
            batch_sizes,
            generators,
            layer_artifacts,
            weight_files,
            truth: j.req("truth")?.as_str()?.to_string(),
        })
    }

    /// Reconstruct the [`NetworkCfg`] the manifest describes (layer by
    /// layer, so divergence from the built-in config is detectable).
    pub fn network_cfg(&self, name: &str) -> Result<NetworkCfg> {
        let j = self.net_json(name)?;
        let m = self.network(name)?;
        let layers: Vec<DeconvLayerCfg> = j
            .req("layers")?
            .as_arr()?
            .iter()
            .map(|l| {
                Ok(DeconvLayerCfg {
                    c_in: l.req("c_in")?.as_usize()?,
                    c_out: l.req("c_out")?.as_usize()?,
                    k: l.req("k")?.as_usize()?,
                    stride: l.req("stride")?.as_usize()?,
                    padding: l.req("padding")?.as_usize()?,
                    i_h: l.req("i_h")?.as_usize()?,
                })
            })
            .collect::<Result<_>>()?;
        ensure!(!layers.is_empty(), "manifest/{name} has no layers");
        // optional datapath precision ("f32" when absent; "q8.8"-style
        // strings select the fixed-point serving path)
        let precision = match j.get("precision") {
            Some(p) => p.as_str()?.parse::<Precision>()?,
            None => Precision::F32,
        };
        Ok(NetworkCfg {
            name: m.name,
            z_dim: m.z_dim,
            layers,
            image_channels: m.image_channels,
            image_size: m.image_size,
            tile: m.tile,
            precision,
        })
    }

    /// Load every layer's `(weights, bias)` pair.
    pub fn load_weights(&self, name: &str) -> Result<Vec<(Tensor, Vec<f32>)>> {
        let m = self.network(name)?;
        let mut out = Vec::with_capacity(m.weight_files.len());
        for (wf, bf) in &m.weight_files {
            let w = Tensor::read_npy(&self.root.join(wf))
                .with_context(|| format!("loading weights {wf}"))?;
            ensure!(
                w.shape().len() == 4,
                "weight file {wf} is not rank-4 (got {:?})",
                w.shape()
            );
            let (bshape, bias) = crate::tensor::read_npy_f32(&self.root.join(bf))
                .with_context(|| format!("loading bias {bf}"))?;
            ensure!(
                bshape.len() == 1 && bshape[0] == bias.len(),
                "bias file {bf} is not a vector"
            );
            out.push((w, bias));
        }
        Ok(out)
    }

    /// Load the ground-truth sample batch `[N, C, H, W]`.
    pub fn load_truth(&self, name: &str) -> Result<Tensor> {
        let m = self.network(name)?;
        let t = Tensor::read_npy(&self.root.join(&m.truth))
            .with_context(|| format!("loading truth {}", m.truth))?;
        ensure!(
            t.shape().len() == 4,
            "truth batch is not rank-4 (got {:?})",
            t.shape()
        );
        Ok(t)
    }

    /// Resolve the generator artifact for a wanted batch size: the
    /// smallest exported bucket ≥ `want`, else the largest (the dynamic
    /// batcher then splits).  Returns `(bucket, path)`.
    pub fn generator_hlo(
        &self,
        name: &str,
        want: usize,
    ) -> Result<(usize, PathBuf)> {
        let m = self.network(name)?;
        ensure!(!m.generators.is_empty(), "{name}: no generator artifacts");
        let bucket = m
            .batch_sizes
            .iter()
            .copied()
            .filter(|b| *b >= want)
            .min()
            .unwrap_or_else(|| {
                m.batch_sizes.iter().copied().max().unwrap_or(1)
            });
        let file = m.generators.get(&bucket).ok_or_else(|| {
            anyhow::anyhow!("{name}: bucket {bucket} missing a generator")
        })?;
        Ok((bucket, self.root.join(file)))
    }

    /// Path of layer `i`'s single-layer HLO artifact.
    pub fn layer_hlo(&self, name: &str, i: usize) -> Result<PathBuf> {
        let m = self.network(name)?;
        let file = m.layer_artifacts.get(i).ok_or_else(|| {
            anyhow::anyhow!(
                "{name}: layer {i} out of range ({} artifacts)",
                m.layer_artifacts.len()
            )
        })?;
        Ok(self.root.join(file))
    }

    /// Names of all networks in the manifest.
    pub fn network_names(&self) -> Result<Vec<String>> {
        Ok(self
            .manifest
            .req("networks")?
            .as_obj()?
            .keys()
            .cloned()
            .collect())
    }

    /// Load a quantized-weight sidecar previously written by
    /// [`export_quantized`]: the format plus the raw per-layer storage
    /// words and calibrated scales.  Feed into
    /// [`QuantizedGenerator::from_raw`] — bit-exact against the
    /// exported generator.
    pub fn load_quantized(
        &self,
        name: &str,
    ) -> Result<(QFormat, Vec<QuantLayerRaw>)> {
        let path = self.root.join(format!("{name}_quant.json"));
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {}", path.display()))?;
        let j = parse_json(&text)
            .with_context(|| format!("parsing {}", path.display()))?;
        let version = j.req("version")?.as_usize()?;
        ensure!(
            version == 1 || version == 2,
            "unsupported quant sidecar version {version}"
        );
        let bits = j.req("bits")?.as_usize()? as u32;
        let frac = j.req("frac")?.as_usize()? as u32;
        let mut layers = Vec::new();
        for l in j.req("layers")?.as_arr()? {
            let wf = l.req("w")?.as_str()?;
            let bf = l.req("b")?.as_str()?;
            let (w_shape, w_raw) = read_npy_i32(&self.root.join(wf))
                .with_context(|| format!("loading quantized weights {wf}"))?;
            let (b_shape, b_raw) = read_npy_i32(&self.root.join(bf))
                .with_context(|| format!("loading quantized bias {bf}"))?;
            ensure!(
                w_shape.len() == 4,
                "quantized weight file {wf} is not rank-4"
            );
            ensure!(
                b_shape.len() == 1 && b_shape[0] == b_raw.len(),
                "quantized bias file {bf} is not a vector"
            );
            // v2 carries per-output-channel exponents; v1's single
            // per-layer exponent expands to a uniform vector.
            let scale_exps: Vec<i32> = if version >= 2 {
                let arr = l.req("scale_exps")?.as_arr()?;
                ensure!(
                    arr.len() == b_raw.len(),
                    "scale_exps length {} != {} output channels in {wf}",
                    arr.len(),
                    b_raw.len()
                );
                arr.iter()
                    .map(|e| Ok(e.as_f64()? as i32))
                    .collect::<Result<_>>()?
            } else {
                let e = l.req("scale_exp")?.as_f64()? as i32;
                vec![e; b_raw.len()]
            };
            layers.push(QuantLayerRaw {
                w_shape,
                w_raw,
                b_raw,
                scale_exps,
            });
        }
        ensure!(!layers.is_empty(), "{name}: empty quant sidecar");
        Ok((QFormat::new(bits, frac), layers))
    }

    /// Is every file the manifest references present on disk?  `false`
    /// for synthetic sets (no HLO text) and partial exports.
    pub fn is_complete(&self) -> bool {
        let Ok(names) = self.network_names() else {
            return false;
        };
        for name in names {
            let Ok(m) = self.network(&name) else {
                return false;
            };
            let mut files: Vec<String> =
                m.generators.values().cloned().collect();
            files.extend(m.layer_artifacts.iter().cloned());
            files.push(m.truth.clone());
            for (w, b) in &m.weight_files {
                files.push(w.clone());
                files.push(b.clone());
            }
            if files.iter().any(|f| !self.root.join(f).exists()) {
                return false;
            }
        }
        true
    }
}

fn default_candidates() -> Vec<PathBuf> {
    let mut out = Vec::new();
    if let Ok(env) = std::env::var("EDGEDCNN_ARTIFACTS") {
        out.push(PathBuf::from(env));
    }
    out.push(PathBuf::from("artifacts"));
    out.push(PathBuf::from("../artifacts"));
    out
}

/// Open the default artifact set for a test/bench, or print a skip
/// notice and return `None`.  Requires a *complete* set (all HLO, weight
/// and truth files present): the guarded tests assert properties of
/// trained artifacts that synthetic weight sets do not satisfy.
pub fn artifacts_or_skip() -> Option<ArtifactDir> {
    match ArtifactDir::open_default() {
        Ok(a) if a.is_complete() => Some(a),
        Ok(a) => {
            eprintln!(
                "(skipping: artifact set at {} is incomplete — run \
                 `make artifacts`)",
                a.root.display()
            );
            None
        }
        Err(_) => {
            eprintln!("(skipping: no artifacts — run `make artifacts`)");
            None
        }
    }
}

/// Batch buckets mirrored from `python/compile/aot.py::BATCH_SIZES`.
fn synthetic_buckets(name: &str) -> Vec<usize> {
    match name {
        "celeba" => vec![1, 4],
        _ => vec![1, 4, 8],
    }
}

/// Fabricate a weights+truth+manifest artifact set from seeded random
/// draws (no training, no HLO text).  Enough for the fallback runtime,
/// the serving coordinator and the parallel-engine tests to run the full
/// stack without the Python build layer.
pub fn write_synthetic(
    dir: &Path,
    networks: &[&str],
    truth_samples: usize,
    seed: u64,
) -> Result<ArtifactDir> {
    ensure!(truth_samples >= 2, "need at least two truth samples");
    std::fs::create_dir_all(dir.join("weights"))
        .with_context(|| format!("creating {}", dir.display()))?;
    let mut nets_json = String::new();
    for (ni, name) in networks.iter().enumerate() {
        let cfg = network_by_name(name)?;
        let mut rng = Rng::seed_from_u64(seed ^ (ni as u64).wrapping_mul(0x9E37));

        let mut weights_json = String::new();
        for (i, layer) in cfg.layers.iter().enumerate() {
            let w = Tensor::from_fn(
                vec![layer.c_in, layer.c_out, layer.k, layer.k],
                |_| 0.05 * rng.normal_f32(),
            );
            let b: Vec<f32> =
                (0..layer.c_out).map(|_| 0.01 * rng.normal_f32()).collect();
            let wf = format!("weights/{name}_l{i}_w.npy");
            let bf = format!("weights/{name}_l{i}_b.npy");
            w.write_npy(&dir.join(&wf))?;
            crate::tensor::write_npy_f32(&dir.join(&bf), &[b.len()], &b)?;
            if i > 0 {
                weights_json.push_str(", ");
            }
            weights_json
                .push_str(&format!(r#"{{"w": "{wf}", "b": "{bf}"}}"#));
        }

        // truth batch: tanh-squashed draws so every value is in (-1, 1)
        let truth = Tensor::from_fn(
            vec![
                truth_samples,
                cfg.image_channels,
                cfg.image_size,
                cfg.image_size,
            ],
            |_| (0.7 * rng.normal_f32()).tanh(),
        );
        let truth_file = format!("{name}_truth.npy");
        truth.write_npy(&dir.join(&truth_file))?;

        let buckets = synthetic_buckets(name);
        let batch_sizes_json = buckets
            .iter()
            .map(|b| b.to_string())
            .collect::<Vec<_>>()
            .join(", ");
        let generators_json = buckets
            .iter()
            .map(|b| format!(r#""{b}": "{name}_gen_b{b}.hlo.txt""#))
            .collect::<Vec<_>>()
            .join(", ");
        let layer_artifacts_json = (0..cfg.layers.len())
            .map(|i| format!(r#""{name}_layer{i}_b1.hlo.txt""#))
            .collect::<Vec<_>>()
            .join(", ");
        let layers_json = cfg
            .layers
            .iter()
            .map(|l| {
                format!(
                    r#"{{"c_in": {}, "c_out": {}, "k": {}, "stride": {}, "padding": {}, "i_h": {}, "o_h": {}, "ops": {}, "macs": {}}}"#,
                    l.c_in,
                    l.c_out,
                    l.k,
                    l.stride,
                    l.padding,
                    l.i_h,
                    l.o_h(),
                    l.ops(),
                    l.macs()
                )
            })
            .collect::<Vec<_>>()
            .join(", ");
        let param_order_json = std::iter::once(r#""z""#.to_string())
            .chain((0..cfg.layers.len()).flat_map(|i| {
                [format!(r#""w{i}""#), format!(r#""b{i}""#)]
            }))
            .collect::<Vec<_>>()
            .join(", ");

        if ni > 0 {
            nets_json.push_str(",\n");
        }
        nets_json.push_str(&format!(
            r#" "{name}": {{
  "name": "{name}",
  "synthetic": true,
  "z_dim": {z_dim},
  "tile": {tile},
  "image_size": {image_size},
  "image_channels": {image_channels},
  "batch_sizes": [{batch_sizes_json}],
  "generators": {{{generators_json}}},
  "layer_artifacts": [{layer_artifacts_json}],
  "weights": [{weights_json}],
  "truth": "{truth_file}",
  "train_log": "train_log_{name}.json",
  "layers": [{layers_json}],
  "param_order": [{param_order_json}]
 }}"#,
            z_dim = cfg.z_dim,
            tile = cfg.tile,
            image_size = cfg.image_size,
            image_channels = cfg.image_channels,
        ));
    }

    let manifest = format!(
        "{{\n \"version\": 1,\n \"networks\": {{\n{nets_json}\n }}\n}}\n"
    );
    let mut f = std::fs::File::create(dir.join("manifest.json"))?;
    f.write_all(manifest.as_bytes())?;
    ArtifactDir::open(dir)
}

/// Export a quantized weight set next to an artifact directory: per
/// layer an `<i2>`/`<i4>` npy pair (`weights/<net>_l<i>_{wq,bq}.npy`)
/// plus a versioned `<net>_quant.json` sidecar (schema v2) carrying the
/// format and the calibrated per-output-channel scale exponents
/// (`scale_exps`; v1 sidecars with a scalar per-layer `scale_exp` still
/// import).  Returns the sidecar path.
pub fn export_quantized(
    dir: &Path,
    name: &str,
    gen: &QuantizedGenerator,
) -> Result<PathBuf> {
    let fmt = gen.format();
    let raw = gen.export_raw();
    ensure!(!raw.is_empty(), "nothing to export");
    std::fs::create_dir_all(dir.join("weights"))
        .with_context(|| format!("creating {}", dir.display()))?;
    let mut layers_json = String::new();
    for (i, l) in raw.iter().enumerate() {
        let wf = format!("weights/{name}_l{i}_wq.npy");
        let bf = format!("weights/{name}_l{i}_bq.npy");
        if fmt.bits <= 16 {
            let w16: Vec<i16> = l.w_raw.iter().map(|v| *v as i16).collect();
            write_npy_i16(&dir.join(&wf), &l.w_shape, &w16)?;
            let b16: Vec<i16> = l.b_raw.iter().map(|v| *v as i16).collect();
            write_npy_i16(&dir.join(&bf), &[b16.len()], &b16)?;
        } else {
            write_npy_i32(&dir.join(&wf), &l.w_shape, &l.w_raw)?;
            write_npy_i32(&dir.join(&bf), &[l.b_raw.len()], &l.b_raw)?;
        }
        if i > 0 {
            layers_json.push_str(",\n");
        }
        let exps = l
            .scale_exps
            .iter()
            .map(|e| e.to_string())
            .collect::<Vec<_>>()
            .join(", ");
        layers_json.push_str(&format!(
            r#"  {{"w": "{wf}", "b": "{bf}", "scale_exps": [{exps}]}}"#,
        ));
    }
    let sidecar = format!(
        "{{\n \"version\": 2,\n \"network\": \"{name}\",\n \"bits\": {},\n \
         \"frac\": {},\n \"layers\": [\n{layers_json}\n ]\n}}\n",
        fmt.bits, fmt.frac
    );
    let path = dir.join(format!("{name}_quant.json"));
    let mut f = std::fs::File::create(&path)
        .with_context(|| format!("creating {}", path.display()))?;
    f.write_all(sidecar.as_bytes())?;
    Ok(path)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::Rounding;
    use crate::util::TempDir;

    #[test]
    fn quantized_export_import_roundtrip() {
        let dir = TempDir::new().unwrap();
        let a = write_synthetic(dir.path(), &["mnist"], 2, 5).unwrap();
        let weights = a.load_weights("mnist").unwrap();
        for fmt in [
            QFormat::new(8, 6),
            QFormat::new(16, 8),
            QFormat::new(32, 16),
        ] {
            let gen =
                QuantizedGenerator::quantize(fmt, &weights, Rounding::Nearest)
                    .unwrap();
            let path = export_quantized(dir.path(), "mnist", &gen).unwrap();
            assert!(path.exists());
            let (got_fmt, raw) = a.load_quantized("mnist").unwrap();
            assert_eq!(got_fmt, fmt);
            assert_eq!(raw, gen.export_raw(), "raw bits must roundtrip");
            let back = QuantizedGenerator::from_raw(got_fmt, &raw).unwrap();
            assert_eq!(back.export_raw(), gen.export_raw());
        }
        // missing sidecar errors cleanly
        assert!(a.load_quantized("celeba").is_err());
    }

    #[test]
    fn v1_sidecar_with_per_layer_scale_still_loads() {
        let dir = TempDir::new().unwrap();
        let a = write_synthetic(dir.path(), &["mnist"], 2, 5).unwrap();
        let weights = a.load_weights("mnist").unwrap();
        let gen = QuantizedGenerator::quantize(
            QFormat::new(16, 8),
            &weights,
            Rounding::Nearest,
        )
        .unwrap();
        export_quantized(dir.path(), "mnist", &gen).unwrap();
        // rewrite the v2 sidecar as the legacy v1 schema: scalar
        // per-layer "scale_exp" instead of the per-channel array
        let n_layers = gen.export_raw().len();
        let mut layers_json = String::new();
        for i in 0..n_layers {
            if i > 0 {
                layers_json.push_str(",\n");
            }
            layers_json.push_str(&format!(
                r#"  {{"w": "weights/mnist_l{i}_wq.npy", "b": "weights/mnist_l{i}_bq.npy", "scale_exp": -3}}"#,
            ));
        }
        let v1 = format!(
            "{{\n \"version\": 1,\n \"network\": \"mnist\",\n \"bits\": 16,\n \
             \"frac\": 8,\n \"layers\": [\n{layers_json}\n ]\n}}\n"
        );
        std::fs::write(dir.path().join("mnist_quant.json"), v1).unwrap();
        let (fmt, raw) = a.load_quantized("mnist").unwrap();
        assert_eq!(fmt, QFormat::new(16, 8));
        for l in &raw {
            // the scalar expands to one exponent per output channel
            assert_eq!(l.scale_exps, vec![-3; l.b_raw.len()]);
        }
        // and the expanded form still builds a generator
        assert!(QuantizedGenerator::from_raw(fmt, &raw).is_ok());
    }

    #[test]
    fn manifest_precision_field_parses() {
        let dir = TempDir::new().unwrap();
        let a = write_synthetic(dir.path(), &["mnist"], 2, 5).unwrap();
        assert_eq!(
            a.network_cfg("mnist").unwrap().precision,
            Precision::F32,
            "absent field defaults to f32"
        );
    }

    #[test]
    fn synthetic_roundtrip_mnist() {
        let dir = TempDir::new().unwrap();
        let a = write_synthetic(dir.path(), &["mnist"], 4, 7).unwrap();
        let m = a.network("mnist").unwrap();
        assert_eq!(m.z_dim, 100);
        assert_eq!(m.batch_sizes, vec![1, 4, 8]);
        assert_eq!(m.weight_files.len(), 3);
        let cfg = a.network_cfg("mnist").unwrap();
        assert_eq!(cfg.layers, network_by_name("mnist").unwrap().layers);
        let weights = a.load_weights("mnist").unwrap();
        assert_eq!(weights.len(), 3);
        assert_eq!(weights[0].0.shape(), &[100, 128, 7, 7]);
        assert_eq!(weights[2].1.len(), 1);
        let truth = a.load_truth("mnist").unwrap();
        assert_eq!(truth.shape(), &[4, 1, 28, 28]);
        assert!(truth.data().iter().all(|v| v.abs() <= 1.0));
    }

    #[test]
    fn synthetic_is_incomplete_without_hlo() {
        let dir = TempDir::new().unwrap();
        let a = write_synthetic(dir.path(), &["mnist"], 2, 1).unwrap();
        assert!(!a.is_complete(), "no HLO text → incomplete by design");
    }

    #[test]
    fn bucket_selection_rounds_up_then_caps() {
        let dir = TempDir::new().unwrap();
        let a = write_synthetic(dir.path(), &["mnist"], 2, 1).unwrap();
        assert_eq!(a.generator_hlo("mnist", 1).unwrap().0, 1);
        assert_eq!(a.generator_hlo("mnist", 3).unwrap().0, 4);
        assert_eq!(a.generator_hlo("mnist", 8).unwrap().0, 8);
        assert_eq!(a.generator_hlo("mnist", 100).unwrap().0, 8);
    }

    #[test]
    fn missing_dir_and_network_error() {
        assert!(ArtifactDir::open(Path::new("/nonexistent/x")).is_err());
        let dir = TempDir::new().unwrap();
        let a = write_synthetic(dir.path(), &["mnist"], 2, 1).unwrap();
        assert!(a.network("imagenet").is_err());
        assert!(a.layer_hlo("mnist", 99).is_err());
    }

    #[test]
    fn determinism_given_seed() {
        let d1 = TempDir::new().unwrap();
        let d2 = TempDir::new().unwrap();
        let a = write_synthetic(d1.path(), &["mnist"], 2, 42).unwrap();
        let b = write_synthetic(d2.path(), &["mnist"], 2, 42).unwrap();
        let wa = a.load_weights("mnist").unwrap();
        let wb = b.load_weights("mnist").unwrap();
        assert_eq!(wa[0].0.data(), wb[0].0.data());
    }
}
