//! Design-space exploration (Section V-A, Fig. 5) using the roofline
//! methodology of Zhang et al. (FPGA'15): enumerate legal square output
//! tiling factors, compute each design's computation-to-communication
//! (CTC) ratio and attainable throughput, discard designs that demand
//! more bandwidth than the platform sustains (left of the peak-bandwidth
//! slope) or that do not fit the fabric, and pick the throughput-optimal
//! survivor as the network's unified `T_OH`.

mod roofline;

pub use roofline::{explore, optimal_tile, DesignPoint};
