//! Design-space exploration (Section V-A, Fig. 5) using the roofline
//! methodology of Zhang et al. (FPGA'15): enumerate legal square output
//! tiling factors, compute each design's computation-to-communication
//! (CTC) ratio and attainable throughput, discard designs that demand
//! more bandwidth than the platform sustains (left of the peak-bandwidth
//! slope) or that do not fit the fabric, and pick the throughput-optimal
//! survivor as the network's unified `T_OH`.

//!
//! The cache-roofline sibling ([`cache`]) scores the *software* side of
//! the same tile space: L1/L2 residency and per-byte reuse of every
//! legal [`crate::deconv::BlockSchedule`], so the CPU blocking, the CU
//! cycle model and the DSE all sweep one shared geometry.

mod cache;
mod roofline;

pub use cache::{
    best_block, explore_blocks, score_block_schedule, CacheModel,
    CachePoint,
};
pub use roofline::{explore, optimal_tile, DesignPoint};
