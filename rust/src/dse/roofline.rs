//! Roofline evaluation of tiling design points (Zhang et al., FPGA'15,
//! as applied in the paper's Section V-A).

use crate::config::{FpgaBoard, NetworkCfg};
use crate::deconv::input_tile_extent;
use crate::fpga::{estimate_resources, CuModel, CuWorkload, Utilization};

/// One candidate design (a square output tiling factor for the whole
/// network — the paper optimizes `T_OH` globally across layers).
#[derive(Debug, Clone, Copy)]
pub struct DesignPoint {
    pub tile: usize,
    /// Computation-to-communication ratio, ops per DDR byte.
    pub ctc: f64,
    /// Compute roof at this design's CU occupancy/efficiency, GOps/s.
    pub comp_roof_gops: f64,
    /// `min(comp_roof, CTC × BW)` — the attainable throughput, GOps/s.
    pub attainable_gops: f64,
    /// Bandwidth needed to sustain the compute roof, bytes/s.
    pub bw_required: f64,
    /// Fabric legality (Table I model).
    pub utilization: Utilization,
    pub fits_resources: bool,
    /// `true` when the design is compute-bound (attainable == compute
    /// roof).  `false` means the point sits *left of the peak-bandwidth
    /// slope* in the Fig. 5 sense: it would need more DDR bandwidth than
    /// STREAM sustains, so its attainable throughput is clamped to
    /// `CTC × BW`.
    pub bandwidth_feasible: bool,
}

/// External-memory traffic of one full-network inference at tile `t`
/// (same accounting as the pipeline simulator: per tile-batch input
/// blocks + per-CU weight streams + one-shot outputs).
fn network_traffic_bytes(net: &NetworkCfg, board: &FpgaBoard, t: usize) -> u64 {
    let mut bytes = 0u64;
    for l in &net.layers {
        let o = l.o_h();
        let te = t.min(o).max(1);
        let t_i = input_tile_extent(te, l.k, l.stride);
        let tiles = o.div_ceil(te).pow(2);
        let workloads = tiles * l.c_out;
        let batches = workloads.div_ceil(board.n_cu) as u64;
        let tiles_per_batch =
            (board.n_cu / l.c_out.min(board.n_cu)).clamp(1, tiles) as u64;
        let input_block = 4 * (l.c_in * t_i * t_i) as u64;
        let weights_per_batch =
            4 * (l.c_in * l.k * l.k) as u64 * l.c_out.min(board.n_cu) as u64;
        bytes += batches * (input_block * tiles_per_batch + weights_per_batch);
        bytes += l.output_bytes();
    }
    bytes
}

/// Aggregate compute roof of the network at tile `t`: total ops divided
/// by the time the CU array needs with every batch's occupancy and
/// per-workload overheads accounted.
fn compute_roof_gops(net: &NetworkCfg, board: &FpgaBoard, t: usize) -> f64 {
    let cu = CuModel::from_board(board);
    let mut total_ops = 0u64;
    let mut total_cycles = 0u64;
    for l in &net.layers {
        let o = l.o_h();
        let te = t.min(o).max(1);
        let tiles = o.div_ceil(te).pow(2);
        let workloads = tiles * l.c_out;
        let batches = workloads.div_ceil(board.n_cu) as u64;
        let wl = CuWorkload {
            c_in: l.c_in,
            taps: l.k * l.k,
            macs_per_tap: te.div_ceil(l.stride).pow(2),
            tile_elems: te * te,
        };
        total_cycles += batches * cu.dense_cycles(&wl);
        total_ops += l.ops();
    }
    let time_s = total_cycles as f64 / board.clock_hz;
    total_ops as f64 / time_s / 1e9
}

/// Evaluate every legal square tile factor for a network on a board.
pub fn explore(net: &NetworkCfg, board: &FpgaBoard) -> Vec<DesignPoint> {
    let s_max = net.layers.iter().map(|l| l.stride).max().unwrap_or(1);
    let o_max = net.layers.iter().map(|l| l.o_h()).max().unwrap_or(2);
    let total_ops: u64 = net.layers.iter().map(|l| l.ops()).sum();

    crate::deconv::legal_tiles(o_max, s_max)
        .into_iter()
        .map(|t| {
            let traffic = network_traffic_bytes(net, board, t);
            let ctc = total_ops as f64 / traffic as f64;
            let comp_roof = compute_roof_gops(net, board, t);
            let bw_roof = ctc * board.stream_bw_bytes / 1e9;
            let attainable = comp_roof.min(bw_roof);
            let bw_required = comp_roof * 1e9 / ctc;
            let utilization = estimate_resources(net, t, board.n_cu);
            DesignPoint {
                tile: t,
                ctc,
                comp_roof_gops: comp_roof,
                attainable_gops: attainable,
                bw_required,
                utilization,
                fits_resources: utilization.fits(board),
                bandwidth_feasible: bw_required <= board.stream_bw_bytes,
            }
        })
        .collect()
}

/// The paper's selection rule: maximize attainable throughput among
/// designs that fit the fabric and sit at/right of the bandwidth slope;
/// break ties toward higher CTC (less DDR pressure), then smaller tile
/// (more spatial parallelism headroom).
pub fn optimal_tile(points: &[DesignPoint]) -> Option<&DesignPoint> {
    points
        .iter()
        .filter(|p| p.fits_resources)
        .max_by(|a, b| {
            let key_a = (a.attainable_gops, a.ctc, -(a.tile as f64));
            let key_b = (b.attainable_gops, b.ctc, -(b.tile as f64));
            key_a.partial_cmp(&key_b).unwrap()
        })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{celeba, mnist, PYNQ_Z2};

    #[test]
    fn explore_produces_legal_points() {
        let pts = explore(&mnist(), &PYNQ_Z2);
        assert!(!pts.is_empty());
        for p in &pts {
            assert!(p.ctc > 0.0);
            assert!(p.attainable_gops > 0.0);
            assert!(p.attainable_gops <= p.comp_roof_gops + 1e-9);
            assert!(p.attainable_gops <= PYNQ_Z2.peak_gops() + 1e-9);
        }
    }

    #[test]
    fn attainable_capped_by_bandwidth_when_infeasible() {
        for net in [mnist(), celeba()] {
            for p in explore(&net, &PYNQ_Z2) {
                if !p.bandwidth_feasible {
                    let bw_roof = p.ctc * PYNQ_Z2.stream_bw_bytes / 1e9;
                    assert!((p.attainable_gops - bw_roof).abs() < 1e-9);
                }
            }
        }
    }

    #[test]
    fn optimal_exists_and_fits() {
        for net in [mnist(), celeba()] {
            let pts = explore(&net, &PYNQ_Z2);
            let best = optimal_tile(&pts).expect("an optimum must exist");
            assert!(best.fits_resources);
            assert!(best.utilization.dsp <= PYNQ_Z2.dsp_total);
        }
    }

    #[test]
    fn ctc_grows_with_tile_overall() {
        // larger tiles refetch fewer input halos → CTC at the largest
        // legal tile exceeds CTC at the smallest
        let pts = explore(&celeba(), &PYNQ_Z2);
        let first = pts.first().unwrap();
        let last = pts.last().unwrap();
        assert!(last.ctc > first.ctc);
    }
}
