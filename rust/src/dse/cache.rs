//! Cache roofline over block schedules — the software sibling of the
//! Fig. 5 roofline: instead of BRAM blocks and DDR bandwidth, the
//! constraints are L1/L2 residency of one micro-/macro-tile's working
//! set, and the merit figure is arithmetic intensity per input byte
//! (how many MACs one cached input block feeds before eviction).
//!
//! The footprint arithmetic lives on [`BlockSchedule`] itself
//! (`l1_footprint_bytes` / `l2_footprint_bytes`), so the DSE scores the
//! *same struct* the CPU kernels execute and `edgedcnn tune` measures —
//! one tile geometry, three consumers.

use crate::deconv::{legal_block_schedules, BlockSchedule};

/// Cache capacities the score is evaluated against.  Defaults model a
/// small edge-class core (32 KiB L1D, 512 KiB per-core L2) — the class
/// of host CPU the paper's Jetson/PYNQ comparison targets.
#[derive(Debug, Clone, Copy)]
pub struct CacheModel {
    pub l1_bytes: usize,
    pub l2_bytes: usize,
}

impl Default for CacheModel {
    fn default() -> Self {
        CacheModel { l1_bytes: 32 << 10, l2_bytes: 512 << 10 }
    }
}

/// One scored block-schedule candidate.
#[derive(Debug, Clone, Copy)]
pub struct CachePoint {
    pub sched: BlockSchedule,
    /// Micro-tile working set (input block + one channel's weights +
    /// accumulator block), bytes.
    pub l1_footprint: usize,
    /// Macro-tile working set (member input blocks + full weights +
    /// one accumulator block), bytes.
    pub l2_footprint: usize,
    pub l1_resident: bool,
    pub l2_resident: bool,
    /// Arithmetic intensity: dense MACs one micro-tile issues per input
    /// byte it streams.  Bigger tiles amortize the Eq. 5 halo, so reuse
    /// grows with `micro` — the cache capacities are what bound it.
    pub reuse: f64,
    /// Ranking figure: reuse × residency factor (1 when the micro-tile
    /// is L1-resident, ½ when only the macro-tile is L2-resident, ⅒
    /// when the schedule spills L2).
    pub score: f64,
}

/// Score one schedule for one layer shape at the given element/
/// accumulator widths.
pub fn score_block_schedule(
    model: &CacheModel,
    sched: BlockSchedule,
    c_in: usize,
    c_out: usize,
    k: usize,
    s: usize,
    elem_bytes: usize,
    acc_bytes: usize,
) -> CachePoint {
    let sched = sched.normalized();
    let l1 = sched.l1_footprint_bytes(k, s, c_in, c_out, elem_bytes, acc_bytes);
    let l2 = sched.l2_footprint_bytes(k, s, c_in, c_out, elem_bytes, acc_bytes);
    let l1_resident = l1 <= model.l1_bytes;
    let l2_resident = l2 <= model.l2_bytes;
    // dense MACs of one micro-tile: c_out workloads of c_in·K²·⌈T/S⌉²
    let t = sched.micro;
    let macs = (c_in * c_out * k * k) as f64
        * (t.div_ceil(s.max(1)) as f64).powi(2);
    let input = sched.input_block_bytes(k, s.max(1), c_in, elem_bytes) as f64;
    let reuse = macs / input.max(1.0);
    let residency = if l1_resident {
        1.0
    } else if l2_resident {
        0.5
    } else {
        0.1
    };
    CachePoint {
        sched,
        l1_footprint: l1,
        l2_footprint: l2,
        l1_resident,
        l2_resident,
        reuse,
        score: reuse * residency,
    }
}

/// Score every legal block schedule for one layer shape.
pub fn explore_blocks(
    model: &CacheModel,
    o_max: usize,
    c_in: usize,
    c_out: usize,
    k: usize,
    s: usize,
    elem_bytes: usize,
    acc_bytes: usize,
) -> Vec<CachePoint> {
    legal_block_schedules(o_max, s.max(1))
        .into_iter()
        .map(|sched| {
            score_block_schedule(
                model, sched, c_in, c_out, k, s, elem_bytes, acc_bytes,
            )
        })
        .collect()
}

/// The cache-optimal candidate: maximize score, break ties toward the
/// smaller micro-tile (finer load balance), then fewer macro tiles.
pub fn best_block(points: &[CachePoint]) -> Option<&CachePoint> {
    points.iter().max_by(|a, b| {
        let key_a = (
            a.score,
            -(a.sched.micro as f64),
            -(a.sched.macro_tiles as f64),
        );
        let key_b = (
            b.score,
            -(b.sched.micro as f64),
            -(b.sched.macro_tiles as f64),
        );
        key_a.partial_cmp(&key_b).unwrap()
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    // the full-bench layer: 32→32 channels, K=4, S=2
    const SHAPE: (usize, usize, usize, usize) = (32, 32, 4, 2);

    #[test]
    fn reuse_grows_with_the_micro_tile() {
        let (c_in, c_out, k, s) = SHAPE;
        let m = CacheModel::default();
        let small = score_block_schedule(
            &m,
            BlockSchedule { micro: 2, macro_tiles: 1, lanes: 4 },
            c_in, c_out, k, s, 4, 4,
        );
        let big = score_block_schedule(
            &m,
            BlockSchedule { micro: 24, macro_tiles: 1, lanes: 4 },
            c_in, c_out, k, s, 4, 4,
        );
        assert!(
            big.reuse > small.reuse,
            "halo amortization: {} vs {}",
            big.reuse,
            small.reuse
        );
        assert!(big.l1_footprint > small.l1_footprint);
        assert!(big.l2_footprint >= big.l1_footprint);
    }

    #[test]
    fn explore_scores_every_legal_schedule() {
        let (c_in, c_out, k, s) = SHAPE;
        let m = CacheModel::default();
        let pts = explore_blocks(&m, 28, c_in, c_out, k, s, 4, 4);
        assert_eq!(
            pts.len(),
            crate::deconv::legal_block_schedules(28, s).len()
        );
        for p in &pts {
            assert!(p.reuse > 0.0);
            assert!(p.score > 0.0);
            assert!(p.score <= p.reuse, "residency can only discount");
            if p.l1_resident {
                assert!(p.l1_footprint <= m.l1_bytes);
            }
        }
        assert!(best_block(&pts).is_some());
        assert!(best_block(&[]).is_none());
    }

    #[test]
    fn tight_caches_prefer_smaller_blocks() {
        let (c_in, c_out, k, s) = SHAPE;
        let roomy = CacheModel { l1_bytes: 8 << 20, l2_bytes: 64 << 20 };
        let tight = CacheModel { l1_bytes: 8 << 10, l2_bytes: 96 << 10 };
        let best_roomy = *best_block(&explore_blocks(
            &roomy, 28, c_in, c_out, k, s, 4, 4,
        ))
        .unwrap();
        let best_tight = *best_block(&explore_blocks(
            &tight, 28, c_in, c_out, k, s, 4, 4,
        ))
        .unwrap();
        // with effectively infinite cache every point is resident, so
        // the biggest reuse (largest micro) wins; squeezing the caches
        // pushes the optimum to a smaller, still-resident working set
        assert!(best_roomy.l1_resident && best_roomy.l2_resident);
        assert!(best_tight.l2_resident, "tight best must not spill");
        assert!(
            best_tight.sched.micro < best_roomy.sched.micro,
            "tight micro {} vs roomy micro {}",
            best_tight.sched.micro,
            best_roomy.sched.micro
        );
        assert!(best_tight.l2_footprint < best_roomy.l2_footprint);
    }

    #[test]
    fn wider_accumulators_inflate_the_footprint() {
        let (c_in, c_out, k, s) = SHAPE;
        let m = CacheModel::default();
        let sched = BlockSchedule { micro: 12, macro_tiles: 4, lanes: 4 };
        let f32p = score_block_schedule(&m, sched, c_in, c_out, k, s, 4, 4);
        let q8 = score_block_schedule(&m, sched, c_in, c_out, k, s, 2, 8);
        // Q8.8 stores half the input bytes but pins 8-byte accumulators
        assert!(q8.l1_footprint != f32p.l1_footprint);
        assert!(
            q8.reuse > f32p.reuse,
            "narrower elements feed more MACs per byte"
        );
    }

    #[test]
    fn int8_shrinks_footprints_and_admits_fatter_schedules() {
        let (c_in, c_out, k, s) = SHAPE;
        let sched = BlockSchedule { micro: 24, macro_tiles: 4, lanes: 8 };
        let m = CacheModel::default();
        // i8: 1-byte elements, 4-byte accumulators — strictly smaller
        // working set than both q8.8 (2, 6) and f32 (4, 4)
        let i8p = score_block_schedule(&m, sched, c_in, c_out, k, s, 1, 4);
        let q16 = score_block_schedule(&m, sched, c_in, c_out, k, s, 2, 6);
        let f32p = score_block_schedule(&m, sched, c_in, c_out, k, s, 4, 4);
        assert!(i8p.l1_footprint < q16.l1_footprint);
        assert!(i8p.l2_footprint < q16.l2_footprint);
        assert!(i8p.l1_footprint < f32p.l1_footprint);
        assert!(i8p.reuse > q16.reuse, "4× the MACs per streamed byte");
        // a cache sized so this fat schedule spills at q8.8 widths but
        // stays resident at i8 — the autotuner headroom the narrow
        // store buys
        let pinch = CacheModel {
            l1_bytes: i8p.l1_footprint,
            l2_bytes: i8p.l2_footprint,
        };
        let i8_pinched =
            score_block_schedule(&pinch, sched, c_in, c_out, k, s, 1, 4);
        let q16_pinched =
            score_block_schedule(&pinch, sched, c_in, c_out, k, s, 2, 6);
        assert!(i8_pinched.l1_resident && i8_pinched.l2_resident);
        assert!(!q16_pinched.l1_resident);
    }
}
