//! Bench: Table II regeneration — the paper's headline experiment.
//! Prints the full FPGA-vs-GPU GOps/s/W table for both networks (50
//! measured runs each) and times the campaign itself, plus serial vs
//! parallel columns for the network-level simulator sweep.
//!
//! (criterion is not available offline; `util::Bencher` provides the
//! warm-up/iterate/report harness — see DESIGN.md §Offline-environment.)
//! Quick mode: `--smoke` or `EDGEDCNN_BENCH_SMOKE=1`.

use edgedcnn::config::{JETSON_TX1, PYNQ_Z2};
use edgedcnn::experiments as exp;
use edgedcnn::util::{bench_header, smoke_mode, Bencher, WorkerPool};

fn main() -> anyhow::Result<()> {
    bench_header("table2_throughput (paper Table II)");
    let smoke = smoke_mode();
    let iters = if smoke { 2 } else { 10 };

    for net in ["mnist", "celeba"] {
        let data = exp::run_table2(net, &PYNQ_Z2, &JETSON_TX1, 50, 42)?;
        println!("{}", exp::render_table2(&data));
    }

    // how fast is one full 50-run measurement campaign?
    for net in ["mnist", "celeba"] {
        let r = Bencher::new(&format!("table2/{net}/50-runs"))
            .iters(iters)
            .run(|| {
                exp::run_table2(net, &PYNQ_Z2, &JETSON_TX1, 50, 42).unwrap()
            });
        println!("{}", r.render());
    }

    // per-layer FPGA pipeline simulation cost (the simulator hot path)
    use edgedcnn::config::network_by_name;
    use edgedcnn::fpga::{
        simulate_layer, simulate_network, simulate_network_par, SimOpts,
    };
    for name in ["mnist", "celeba"] {
        let net = network_by_name(name)?;
        for (i, layer) in net.layers.iter().enumerate() {
            let opts = SimOpts::dense(net.tile);
            let r = Bencher::new(&format!("simulate_layer/{name}/L{}", i + 1))
                .iters(if smoke { 10 } else { 100 })
                .run_with_ops(layer.ops() as f64, || {
                    simulate_layer(layer, &PYNQ_Z2, &opts)
                });
            println!("{}", r.render());
        }

        // serial vs parallel columns for the whole-network sweep
        let opts: Vec<SimOpts> =
            net.layers.iter().map(|_| SimOpts::dense(net.tile)).collect();
        let r = Bencher::new(&format!("simulate_network/{name}/serial"))
            .iters(if smoke { 10 } else { 100 })
            .run(|| simulate_network(&net, &PYNQ_Z2, &opts));
        println!("{}", r.render());
        for workers in [2usize, 4] {
            let pool = WorkerPool::new(workers);
            let r = Bencher::new(&format!(
                "simulate_network/{name}/{workers} workers"
            ))
            .iters(if smoke { 10 } else { 100 })
            .run(|| simulate_network_par(&net, &PYNQ_Z2, &opts, &pool));
            println!("{}", r.render());
        }
    }
    Ok(())
}
