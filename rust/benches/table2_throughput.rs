//! Bench: Table II regeneration — the paper's headline experiment.
//! Prints the full FPGA-vs-GPU GOps/s/W table for both networks (50
//! measured runs each) and times the campaign itself.
//!
//! (criterion is not available offline; `util::Bencher` provides the
//! warm-up/iterate/report harness — see DESIGN.md §Offline-environment.)

use edgedcnn::config::{JETSON_TX1, PYNQ_Z2};
use edgedcnn::experiments as exp;
use edgedcnn::util::{bench_header, Bencher};

fn main() -> anyhow::Result<()> {
    bench_header("table2_throughput (paper Table II)");

    for net in ["mnist", "celeba"] {
        let data = exp::run_table2(net, &PYNQ_Z2, &JETSON_TX1, 50, 42)?;
        println!("{}", exp::render_table2(&data));
    }

    // how fast is one full 50-run measurement campaign?
    for net in ["mnist", "celeba"] {
        let r = Bencher::new(&format!("table2/{net}/50-runs"))
            .iters(10)
            .run(|| {
                exp::run_table2(net, &PYNQ_Z2, &JETSON_TX1, 50, 42).unwrap()
            });
        println!("{}", r.render());
    }

    // per-layer FPGA pipeline simulation cost (the simulator hot path)
    use edgedcnn::config::network_by_name;
    use edgedcnn::fpga::{simulate_layer, SimOpts};
    for name in ["mnist", "celeba"] {
        let net = network_by_name(name)?;
        for (i, layer) in net.layers.iter().enumerate() {
            let opts = SimOpts::dense(net.tile);
            let r = Bencher::new(&format!("simulate_layer/{name}/L{}", i + 1))
                .iters(100)
                .run_with_ops(layer.ops() as f64, || {
                    simulate_layer(layer, &PYNQ_Z2, &opts)
                });
            println!("{}", r.render());
        }
    }
    Ok(())
}
