//! Bench: deconvolution kernel micro-benchmarks across all three Rust
//! algorithms, the serial-vs-parallel reverse-loop engine, and the
//! PJRT-executed AOT artifacts — the numeric hot path audit behind
//! EXPERIMENTS.md §Perf.
//!
//! Quick mode for CI: pass `--smoke` (or set `EDGEDCNN_BENCH_SMOKE=1`)
//! to cut iteration counts so a perf regression in the parallel path
//! fails fast without long runtimes.

use edgedcnn::artifacts::artifacts_or_skip;
use edgedcnn::config::network_by_name;
use edgedcnn::deconv::{
    deconv_reverse_loop, deconv_reverse_loop_par, deconv_standard,
    deconv_tdc, ReverseLoopOpts,
};
use edgedcnn::quant::{quantize_tensor, Element, Q8_8, Rounding};
use edgedcnn::runtime::{
    data_to_literal, has_pjrt, tensor_to_literal, Runtime,
};
use edgedcnn::tensor::Tensor;
use edgedcnn::util::{bench_header, smoke_mode, Bencher, Rng, WorkerPool};

fn main() -> anyhow::Result<()> {
    bench_header("deconv_kernels");
    let smoke = smoke_mode();
    let iters = if smoke { 3 } else { 20 };
    if smoke {
        println!("(smoke mode: {iters} iterations per case)");
    }

    // Rust substrate: the three algorithms on a mid-size layer slice
    let mut rng = Rng::seed_from_u64(1);
    let (c_in, c_out, k, s, p, i_h) = (32, 16, 4, 2, 1, 14);
    let x = Tensor::from_fn(vec![1, c_in, i_h, i_h], |_| {
        rng.range_f32(-1.0, 1.0)
    });
    let w = Tensor::from_fn(vec![c_in, c_out, k, k], |_| {
        rng.range_f32(-1.0, 1.0)
    });
    let b = vec![0.0f32; c_out];
    let layer = edgedcnn::config::DeconvLayerCfg {
        c_in,
        c_out,
        k,
        stride: s,
        padding: p,
        i_h,
    };
    let ops = layer.ops() as f64;

    let r = Bencher::new("rust/standard(Eq.1 scatter)")
        .iters(iters)
        .run_with_ops(ops, || deconv_standard(&x, &w, &b, s, p));
    println!("{}", r.render());
    let r = Bencher::new("rust/reverse-loop(Algorithm 1)")
        .iters(iters)
        .run_with_ops(ops, || {
            deconv_reverse_loop(
                &x,
                &w,
                &b,
                s,
                p,
                ReverseLoopOpts {
                    tile: 12,
                    zero_skip: false,
                },
            )
        });
    println!("{}", r.render());
    let r = Bencher::new("rust/reverse-loop+zero-skip(50%)")
        .iters(iters)
        .run_with_ops(ops, || {
            let mut wz = w.clone();
            for (i, v) in wz.data_mut().iter_mut().enumerate() {
                if i % 2 == 0 {
                    *v = 0.0;
                }
            }
            deconv_reverse_loop(
                &x,
                &wz,
                &b,
                s,
                p,
                ReverseLoopOpts {
                    tile: 12,
                    zero_skip: true,
                },
            )
        });
    println!("{}", r.render());
    let r = Bencher::new("rust/tdc(stride^2 transform)")
        .iters(iters)
        .run_with_ops(ops, || deconv_tdc(&x, &w, &b, s, p));
    println!("{}", r.render());

    // Quantized column: the same reverse-loop kernel monomorphized at
    // Q8.8 fixed point — the datapath the FPGA actually runs.  A perf
    // regression here fails the CI bench-smoke job fast.
    let xq = quantize_tensor::<i16, 8>(&x, Rounding::Nearest);
    let wq = quantize_tensor::<i16, 8>(&w, Rounding::Nearest);
    let bq: Vec<Q8_8> = b.iter().map(|v| Q8_8::from_f32(*v)).collect();
    let r = Bencher::new("rust/reverse-loop-q8.8(fixed-point)")
        .iters(iters)
        .run_with_ops(ops, || {
            deconv_reverse_loop(
                &xq,
                &wq,
                &bq,
                s,
                p,
                ReverseLoopOpts {
                    tile: 12,
                    zero_skip: false,
                },
            )
        });
    println!("{}", r.render());
    let pool_q = WorkerPool::new(4);
    let r = Bencher::new("rust/reverse-loop-q8.8/4 workers")
        .iters(iters)
        .run_with_ops(ops, || {
            deconv_reverse_loop_par(
                &xq,
                &wq,
                &bq,
                s,
                p,
                ReverseLoopOpts {
                    tile: 12,
                    zero_skip: false,
                },
                &pool_q,
            )
        });
    println!("{}", r.render());

    // Parallel engine: serial vs parallel columns on a batch-4 slice
    // (36 tile jobs at T=12 — enough spatial parallelism to shard).
    let batch = 4usize;
    let xb = Tensor::from_fn(vec![batch, c_in, i_h, i_h], |_| {
        rng.range_f32(-1.0, 1.0)
    });
    let par_ops = ops * batch as f64;
    let opts = ReverseLoopOpts {
        tile: 12,
        zero_skip: false,
    };
    let serial = Bencher::new("rust/reverse-loop-par/serial(1 worker)")
        .iters(iters)
        .run_with_ops(par_ops, || {
            deconv_reverse_loop(&xb, &w, &b, s, p, opts)
        });
    println!("{}", serial.render());
    let mut at4 = None;
    for workers in [2usize, 4, 8] {
        let pool = WorkerPool::new(workers);
        let r = Bencher::new(&format!(
            "rust/reverse-loop-par/{workers} workers"
        ))
        .iters(iters)
        .run_with_ops(par_ops, || {
            deconv_reverse_loop_par(&xb, &w, &b, s, p, opts, &pool)
        });
        println!("{}", r.render());
        if workers == 4 {
            at4 = Some(r.mean_s);
        }
    }
    if let Some(t4) = at4 {
        println!(
            "parallel speedup at 4 workers: {:.2}x over serial \
             (host has {} cores)",
            serial.mean_s / t4,
            std::thread::available_parallelism()
                .map(|n| n.get())
                .unwrap_or(1)
        );
    }

    // PJRT-executed AOT artifacts: per-layer + full generator
    let Some(artifacts) = artifacts_or_skip() else {
        println!("(skipping PJRT benches — run `make artifacts`)");
        return Ok(());
    };
    let runtime = Runtime::cpu()?;
    for name in ["mnist", "celeba"] {
        let net = network_by_name(name)?;
        if has_pjrt() {
            for (i, layer) in net.layers.iter().enumerate() {
                let hlo = runtime.load_hlo(&artifacts.layer_hlo(name, i)?)?;
                let mut rng = Rng::seed_from_u64(i as u64);
                let x = Tensor::from_fn(
                    vec![1, layer.c_in, layer.i_h, layer.i_h],
                    |_| rng.range_f32(-1.0, 1.0),
                );
                let w = Tensor::from_fn(
                    vec![layer.c_in, layer.c_out, layer.k, layer.k],
                    |_| 0.05 * rng.normal_f32(),
                );
                let b = vec![0.0f32; layer.c_out];
                let inputs = vec![
                    tensor_to_literal(&x)?,
                    tensor_to_literal(&w)?,
                    data_to_literal(&b, &[layer.c_out])?,
                ];
                let out_shape =
                    vec![1, layer.c_out, layer.o_h(), layer.o_h()];
                let r = Bencher::new(&format!("pjrt/{name}/layer{i}"))
                    .iters(iters.min(10))
                    .run_with_ops(layer.ops() as f64, || {
                        hlo.run_to_tensor(&inputs, out_shape.clone()).unwrap()
                    });
                println!("{}", r.render());
            }
        } else {
            println!(
                "(skipping pjrt/{name}/layer benches — built without the \
                 `pjrt` feature)"
            );
        }
        // full generator at each exported batch bucket (runs on either
        // backend; the fallback routes through the parallel substrate)
        let weights = artifacts.load_weights(name)?;
        let manifest = artifacts.network(name)?;
        for &bs in &manifest.batch_sizes {
            let exe = runtime.load_generator(&artifacts, name, bs)?;
            let mut rng = Rng::seed_from_u64(77);
            let z = Tensor::from_fn(vec![bs, net.z_dim], |_| rng.normal_f32());
            let r = Bencher::new(&format!("gen/{name}/generator_b{bs}"))
                .iters(iters.min(10))
                .run_with_ops((net.total_ops() * bs as u64) as f64, || {
                    exe.generate(&z, &weights).unwrap()
                });
            println!("{}", r.render());
        }
    }
    Ok(())
}
