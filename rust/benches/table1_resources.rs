//! Bench: Table I regeneration + resource-model scaling study (CU count
//! and tile-factor sensitivity — the legality surface the DSE explores).

use edgedcnn::config::{network_by_name, PYNQ_Z2};
use edgedcnn::experiments as exp;
use edgedcnn::fpga::estimate_resources;
use edgedcnn::util::{bench_header, Bencher};

fn main() -> anyhow::Result<()> {
    bench_header("table1_resources (paper Table I)");

    print!("{}", exp::render_table1(&exp::run_table1(&PYNQ_Z2)?));

    println!("\nscaling surface (CelebA):");
    let net = network_by_name("celeba")?;
    println!("{:>6} {:>6} {:>8} {:>8} {:>9} {:>8}  fits", "n_cu", "T", "DSP", "BRAM", "FF", "LUT");
    for n_cu in [4, 8, 16, 24, 32] {
        for t in [8, 16, 24, 32] {
            let u = estimate_resources(&net, t, n_cu);
            println!(
                "{:>6} {:>6} {:>8} {:>8} {:>9} {:>8}  {}",
                n_cu,
                t,
                u.dsp,
                u.bram18,
                u.ff,
                u.lut,
                if u.fits(&PYNQ_Z2) { "yes" } else { "NO" }
            );
        }
    }

    let r = Bencher::new("resources/full-table1")
        .iters(1000)
        .run(|| exp::run_table1(&PYNQ_Z2).unwrap());
    println!("\n{}", r.render());
    Ok(())
}
