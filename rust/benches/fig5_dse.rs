//! Bench: Fig. 5 regeneration — the roofline design-space exploration.
//! Prints the full candidate table per network and times the sweep.

use edgedcnn::config::PYNQ_Z2;
use edgedcnn::experiments as exp;
use edgedcnn::util::{bench_header, Bencher};

fn main() -> anyhow::Result<()> {
    bench_header("fig5_dse (paper Fig. 5)");

    for net in ["mnist", "celeba"] {
        let data = exp::run_fig5(net, &PYNQ_Z2)?;
        println!("{}", exp::render_fig5(&data));
    }

    for net in ["mnist", "celeba"] {
        let r = Bencher::new(&format!("dse/{net}/full-sweep"))
            .iters(50)
            .run(|| exp::run_fig5(net, &PYNQ_Z2).unwrap());
        println!("{}", r.render());
    }
    Ok(())
}
