//! Bench: Fig. 6 regeneration — the sparsity sweep (FPGA zero-skip
//! speed-up, MMD degradation, Eq. 6 trade-off).  Uses the trained
//! artifacts; prints the full curve and times one sweep.

use edgedcnn::artifacts::artifacts_or_skip;
use edgedcnn::config::PYNQ_Z2;
use edgedcnn::experiments as exp;
use edgedcnn::util::{bench_header, Bencher};

fn main() -> anyhow::Result<()> {
    bench_header("fig6_sparsity (paper Fig. 6)");
    let Some(artifacts) = artifacts_or_skip() else {
        println!("artifacts missing — run `make artifacts` first");
        return Ok(());
    };

    let levels = exp::default_levels();
    for (net, samples) in [("mnist", 48usize), ("celeba", 16usize)] {
        let data =
            exp::run_fig6(net, &PYNQ_Z2, &artifacts, &levels, samples, 7)?;
        println!("{}", exp::render_fig6(&data));
    }

    // timing: one small sweep (pure-Rust forward — deterministic cost)
    let small = vec![0.0, 0.5, 0.9];
    let r = Bencher::new("fig6/mnist/3-levels-16-samples")
        .iters(5)
        .run(|| {
            exp::run_fig6(
                "mnist", &PYNQ_Z2, &artifacts, &small, 16, 7,
            )
            .unwrap()
        });
    println!("{}", r.render());
    Ok(())
}
