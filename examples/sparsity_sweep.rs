//! Sparsity study (paper Section V-C / Fig. 6): magnitude-prune the
//! trained generators level by level; measure (a) the zero-skipping FPGA
//! speed-up, (b) the MMD degradation of the generated distribution —
//! computed from images actually produced by the pruned AOT artifact on
//! PJRT — and (c) the Eq. 6 trade-off metric with its peak.
//!
//! Run: `cargo run --release --example sparsity_sweep [--pjrt]`
//! (`--pjrt` routes generation through the AOT executable; default uses
//! the numerics-identical pure-Rust forward, which is faster here.)

use edgedcnn::artifacts::ArtifactDir;
use edgedcnn::config::PYNQ_Z2;
use edgedcnn::experiments as exp;
use edgedcnn::runtime::Runtime;

fn main() -> anyhow::Result<()> {
    let use_pjrt = std::env::args().any(|a| a == "--pjrt");
    let artifacts = ArtifactDir::open_default()?;
    let levels = exp::default_levels();

    for net in ["mnist", "celeba"] {
        let samples = if net == "mnist" { 64 } else { 24 };
        let data = if use_pjrt {
            let runtime = Runtime::cpu()?;
            exp::run_fig6_with_runtime(
                net, &PYNQ_Z2, &artifacts, &runtime, &levels, samples, 7,
            )?
        } else {
            exp::run_fig6(net, &PYNQ_Z2, &artifacts, &levels, samples, 7)?
        };
        println!("{}", exp::render_fig6(&data));
        // the paper's qualitative claims, checked live:
        let first = data.curve.first().unwrap();
        let last = data.curve.last().unwrap();
        println!(
            "speed-up at {:.0}% sparsity: {:.2}x (Fig 6a)   \
             MMD {:.4} -> {:.4} (Fig 6b)   Eq.6 peak @ {:.2}\n",
            last.sparsity * 100.0,
            last.speedup,
            first.mmd,
            last.mmd,
            data.peak_sparsity
        );
    }
    Ok(())
}
