//! End-to-end edge serving (DESIGN.md experiment E9).
//!
//! Starts the full coordinator stack — leader thread (intake + dynamic
//! batching into AOT batch buckets) and a **heterogeneous backend pool**
//! (one FIFO lane each for the simulated PYNQ-Z2 datapath, the Jetson
//! TX1 thermal model, and the host CPU numeric path; batches route to
//! the cheapest idle capable device) — then drives an open-loop request
//! workload against both benchmark networks and reports
//! latency/throughput/GOps/s/W with per-backend columns, plus the
//! per-request edge-device annotations.
//!
//! Run: `cargo run --release --example edge_serving`

use edgedcnn::coordinator::{
    BatcherConfig, Coordinator, CoordinatorConfig, WorkloadSpec,
};
use std::time::Duration;

fn main() -> anyhow::Result<()> {
    let coord = Coordinator::start(CoordinatorConfig {
        artifacts_dir: std::env::var("EDGEDCNN_ARTIFACTS")
            .unwrap_or_else(|_| "artifacts".into())
            .into(),
        networks: vec!["mnist".into(), "celeba".into()],
        batcher: BatcherConfig {
            max_batch: 8,
            max_wait: Duration::from_millis(4),
        },
        // default backend pool: fpga0 + gpu0 + cpu0
        ..Default::default()
    })?;
    println!("backend pool: {}", coord.backend_names().join(", "));

    // single-request sanity: deterministic per seed, annotated
    let a = coord.request("mnist").images(2).seed(1234).blocking()?;
    let b = coord.request("mnist").images(2).seed(1234).blocking()?;
    assert_eq!(
        a.images.data(),
        b.images.data(),
        "same seed must reproduce the same images (whichever backend \
         served each request)"
    );
    println!(
        "sanity: 2 mnist images served by {} in {:.2} ms device time \
         (host {:.2} ms) — same work annotated: FPGA {:.2} ms, TX1 GPU \
         {:.2} ms",
        a.backend,
        a.device_time_s * 1e3,
        a.execute_s * 1e3,
        a.fpga_time_s * 1e3,
        a.gpu_time_s * 1e3
    );

    for (network, requests, images) in
        [("mnist", 48usize, 2usize), ("celeba", 16, 1)]
    {
        println!("\n=== serving {network}: {requests} requests × {images} image(s) ===");
        let report = coord.serve_workload(&WorkloadSpec {
            network: network.into(),
            requests,
            images_per_request: images,
            interarrival: Duration::from_millis(2),
            seed: 42,
        })?;
        println!("{}", report.render());
    }
    Ok(())
}
