//! Quickstart: the three-layer path end to end in ~40 lines.
//!
//! 1. open the AOT artifact set (`make artifacts` must have run),
//! 2. compile the MNIST generator on the PJRT CPU client,
//! 3. feed it a latent batch + the trained weights,
//! 4. print an ASCII digit and the edge-device timing annotations.
//!
//! Run: `cargo run --release --example quickstart`

use edgedcnn::artifacts::ArtifactDir;
use edgedcnn::config::{network_by_name, PYNQ_Z2};
use edgedcnn::fpga::{simulate_network, SimOpts};
use edgedcnn::runtime::Runtime;
use edgedcnn::tensor::Tensor;
use edgedcnn::util::Rng;

fn main() -> anyhow::Result<()> {
    let artifacts = ArtifactDir::open_default()?;
    let runtime = Runtime::cpu()?;
    println!("PJRT platform: {}", runtime.platform_name());

    // compile the batch-1 MNIST generator (AOT HLO text -> executable)
    let exe = runtime.load_generator(&artifacts, "mnist", 1)?;
    let weights = artifacts.load_weights("mnist")?;

    // one latent draw -> one image
    let mut rng = Rng::seed_from_u64(7);
    let z = Tensor::from_fn(vec![1, exe.z_dim], |_| rng.normal_f32());
    let t0 = std::time::Instant::now();
    let img = exe.generate(&z, &weights)?;
    let dt = t0.elapsed();

    println!(
        "generated {:?} in {:.2} ms (CPU PJRT)",
        img.shape(),
        dt.as_secs_f64() * 1e3
    );
    // crude ASCII render of the 28x28 digit
    let shades = [' ', '.', ':', 'o', 'O', '#'];
    for y in 0..28 {
        let mut line = String::new();
        for x in 0..28 {
            let v = (img.get4(0, 0, y, x) + 1.0) / 2.0; // [-1,1] -> [0,1]
            let idx = ((v * (shades.len() - 1) as f32).round() as usize)
                .min(shades.len() - 1);
            line.push(shades[idx]);
        }
        println!("{line}");
    }

    // what the same inference costs on the paper's edge devices
    let net = network_by_name("mnist")?;
    let opts: Vec<SimOpts> =
        net.layers.iter().map(|_| SimOpts::dense(net.tile)).collect();
    let sim = simulate_network(&net, &PYNQ_Z2, &opts);
    println!(
        "\nedge annotations: PYNQ-Z2 accelerator {:.2} ms/inference, \
         {:.2} GOps/s/W",
        sim.total_time_s * 1e3,
        sim.gops_per_w
    );
    Ok(())
}
