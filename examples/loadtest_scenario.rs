//! Scenario-driven loadtest (DESIGN.md §Workload).
//!
//! Materializes the built-in `burst` scenario (two-state MMPP arrivals
//! over the f32 network and its `mnist.q` fixed-point twin) into a
//! deterministic trace, drives it open-loop against an fpga+gpu backend
//! pool over repeated seeded trials, and prints the Table-2-style
//! verdict: per-lane latency percentiles (coordinated-omission
//! corrected), SLO attainment, device-latency CV, and throughput with
//! bootstrap confidence intervals — the paper's run-to-run-stability
//! claim as a live experiment.
//!
//! Run: `cargo run --release --example loadtest_scenario`
//! (set `EDGEDCNN_ARTIFACTS`, or run `edgedcnn synth` first).

use edgedcnn::config::{BackendCfg, DeviceKind};
use edgedcnn::workload::{run_loadtest, LoadtestOpts, Scenario, Trace};

fn main() -> anyhow::Result<()> {
    let mut scenario = Scenario::builtin("burst")?;
    scenario.requests = 64;
    let trace = Trace::generate(&scenario)?;
    println!(
        "trace: {} requests over {:.3} s scheduled ({} f32 / {} quantized)",
        trace.events.len(),
        trace.duration_s(),
        trace.events.iter().filter(|e| !e.network.ends_with(".q")).count(),
        trace.events.iter().filter(|e| e.network.ends_with(".q")).count(),
    );

    let report = run_loadtest(
        &trace,
        &LoadtestOpts {
            artifacts_dir: std::env::var("EDGEDCNN_ARTIFACTS")
                .unwrap_or_else(|_| "artifacts".into())
                .into(),
            backends: BackendCfg {
                kinds: vec![DeviceKind::Fpga, DeviceKind::Gpu],
                ..Default::default()
            },
            trials: 3,
            // open loop; every request carries the scenario's deadline
            // and priority class, so the verdict table's deadline-
            // attainment and shed/served-late columns are live
            ..Default::default()
        },
    )?;
    print!("{}", report.render());
    Ok(())
}
