//! Design-space exploration walkthrough (paper Section V-A): regenerate
//! Fig. 5 (all legal tiling candidates, CTC ratios, attainable
//! throughput, the bandwidth slope) and Table I (resource utilization of
//! the selected designs), then show how the chosen T_OH behaves inside
//! the full pipeline simulation.
//!
//! Run: `cargo run --release --example design_space`

use edgedcnn::config::{network_by_name, PYNQ_Z2};
use edgedcnn::experiments as exp;
use edgedcnn::fpga::{simulate_network, SimOpts};

fn main() -> anyhow::Result<()> {
    println!("== Fig. 5: design-space exploration ==\n");
    for net in ["mnist", "celeba"] {
        let data = exp::run_fig5(net, &PYNQ_Z2)?;
        println!("{}", exp::render_fig5(&data));
        let best = &data.points[data.optimal];
        let paper = if net == "mnist" { 12 } else { 24 };
        let paper_pt = data
            .points
            .iter()
            .find(|p| p.tile == paper)
            .expect("paper tile is a candidate");
        println!(
            "model optimum T={} ({:.2} GOps/s attainable); paper chose \
             T={} ({:.2} GOps/s, {:.0}% of optimum)\n",
            best.tile,
            best.attainable_gops,
            paper,
            paper_pt.attainable_gops,
            100.0 * paper_pt.attainable_gops / best.attainable_gops
        );
    }

    println!("== Table I: resources at the paper's T_OH ==\n");
    let rows = exp::run_table1(&PYNQ_Z2)?;
    print!("{}", exp::render_table1(&rows));

    println!("\n== pipeline behaviour at the chosen tiles ==\n");
    for name in ["mnist", "celeba"] {
        let net = network_by_name(name)?;
        let opts: Vec<SimOpts> =
            net.layers.iter().map(|_| SimOpts::dense(net.tile)).collect();
        let sim = simulate_network(&net, &PYNQ_Z2, &opts);
        println!(
            "{name} @ T={}: {:.2} ms/inference, {:.2} GOps/s, \
             {:.2} GOps/s/W",
            net.tile,
            sim.total_time_s * 1e3,
            sim.total_gops,
            sim.gops_per_w
        );
        for (i, l) in sim.layers.iter().enumerate() {
            println!(
                "  L{}: {:.3} ms  occ {:.2}  r/c/w stage cycles \
                 {}/{}/{}",
                i + 1,
                l.time_s * 1e3,
                l.occupancy,
                l.read_cycles,
                l.compute_cycles,
                l.write_cycles
            );
        }
    }
    Ok(())
}
