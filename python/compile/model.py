"""L2 — JAX DCNN generators (and WGAN-GP critics) for the two benchmark
networks of the paper's Fig. 4.

The generators are pure deconvolution stacks (ReLU between layers, tanh on
the output) matching the paper's layer counts:

* **MNIST** — 3 deconvolution layers, ``z(100) → 28×28×1``
* **CelebA** — 5 deconvolution layers, ``z(100) → 64×64×3``

``generator_apply`` can run each deconvolution through either the Pallas
reverse-loop kernel (:mod:`compile.kernels.deconv`, the path that gets
AOT-lowered for the Rust runtime) or the fused-XLA reference
(:mod:`compile.kernels.ref`, the fast path used during WGAN-GP training).
Both are verified against each other by the pytest suite.

Weights stay **parameters** of the lowered function (never baked-in
constants) so the Rust coordinator can feed pruned weight sets for the
sparsity experiments (Fig. 6) without re-lowering.
"""

from __future__ import annotations

from dataclasses import dataclass, field

import jax
import jax.numpy as jnp
import numpy as np

from .kernels.deconv import deconv_pallas
from .kernels.ref import deconv_output_size, deconv_ref, leaky_relu, relu


@dataclass(frozen=True)
class DeconvLayer:
    """One transposed-convolution layer (square kernels, as in the paper)."""

    c_in: int
    c_out: int
    k: int
    stride: int
    padding: int
    i_h: int  # input spatial extent (square)

    @property
    def o_h(self) -> int:
        return deconv_output_size(self.i_h, self.k, self.stride, self.padding)

    def macs(self) -> int:
        """Dense MACs of the reverse-loop schedule — the exact Algorithm 1
        trip count: Σ_{k_h,k_w} |{o_h ≡ f(k_h)}| × |{o_w ≡ f(k_w)}|
        per (c_in, c_out) pair."""
        from .kernels.ref import stride_hole_offsets

        f = stride_hole_offsets(self.k, self.stride, self.padding)
        rows = sum(len(range(int(fk), self.o_h, self.stride)) for fk in f)
        return self.c_in * self.c_out * rows * rows

    def ops(self) -> int:
        """Arithmetic operations (1 MAC = 2 ops), the paper's GOps numerator."""
        return 2 * self.macs()

    def weight_shape(self):
        return (self.c_in, self.c_out, self.k, self.k)


@dataclass(frozen=True)
class NetworkConfig:
    """A DCNN generator = latent dim + deconvolution stack (paper Fig. 4)."""

    name: str
    z_dim: int
    layers: tuple
    image_channels: int
    image_size: int
    tile: int           # paper's unified T_OH (Table I)

    def total_ops(self) -> int:
        return sum(l.ops() for l in self.layers)


def mnist_config() -> NetworkConfig:
    """MNIST generator: 100×1×1 → 128×7×7 → 64×14×14 → 1×28×28."""
    layers = (
        DeconvLayer(100, 128, 7, 1, 0, 1),
        DeconvLayer(128, 64, 4, 2, 1, 7),
        DeconvLayer(64, 1, 4, 2, 1, 14),
    )
    return NetworkConfig("mnist", 100, layers, 1, 28, tile=12)


def celeba_config() -> NetworkConfig:
    """CelebA generator: 100×1×1 → 512×4×4 → 256×8×8 → 128×16×16 →
    64×32×32 → 3×64×64."""
    layers = (
        DeconvLayer(100, 512, 4, 1, 0, 1),
        DeconvLayer(512, 256, 4, 2, 1, 4),
        DeconvLayer(256, 128, 4, 2, 1, 8),
        DeconvLayer(128, 64, 4, 2, 1, 16),
        DeconvLayer(64, 3, 4, 2, 1, 32),
    )
    return NetworkConfig("celeba", 100, layers, 3, 64, tile=24)


CONFIGS = {"mnist": mnist_config, "celeba": celeba_config}


def init_generator_params(cfg: NetworkConfig, key) -> list:
    """DCGAN-style init: W ~ N(0, 0.02), b = 0. Returns [(w, b), ...]."""
    params = []
    for layer in cfg.layers:
        key, sub = jax.random.split(key)
        w = 0.02 * jax.random.normal(sub, layer.weight_shape(), jnp.float32)
        b = jnp.zeros((layer.c_out,), jnp.float32)
        params.append((w, b))
    return params


def generator_apply(params, z, cfg: NetworkConfig, use_pallas: bool = False):
    """Generator forward pass.

    Args:
      params: ``[(w, b)] * n_layers``.
      z: ``[N, z_dim]`` latent batch.
      cfg: network config.
      use_pallas: route each deconvolution through the Pallas reverse-loop
        kernel (AOT/inference path) instead of the fused-XLA reference
        (training path).

    Returns images ``[N, C, H, W]`` in ``[-1, 1]``.
    """
    x = z.reshape(z.shape[0], cfg.z_dim, 1, 1)
    n_layers = len(cfg.layers)
    for i, (layer, (w, b)) in enumerate(zip(cfg.layers, params)):
        if use_pallas:
            x = deconv_pallas(x, w, b, layer.stride, layer.padding, cfg.tile)
        else:
            x = deconv_ref(x, w, b, layer.stride, layer.padding)
        x = jnp.tanh(x) if i == n_layers - 1 else relu(x)
    return x


def generator_layer_apply(x, w, b, layer: DeconvLayer, tile: int,
                          use_pallas: bool = True, activation: str = "relu"):
    """Single-layer forward (per-layer AOT artifacts for Table II benches)."""
    if use_pallas:
        y = deconv_pallas(x, w, b, layer.stride, layer.padding, tile)
    else:
        y = deconv_ref(x, w, b, layer.stride, layer.padding)
    if activation == "relu":
        return relu(y)
    if activation == "tanh":
        return jnp.tanh(y)
    return y


# --------------------------------------------------------------------------
# WGAN-GP critic (training only — never exported, never on the request path)
# --------------------------------------------------------------------------

def critic_layer_shapes(cfg: NetworkConfig) -> list:
    """Mirror of the generator as a strided-conv critic (DCGAN discipline)."""
    shapes = []
    c = cfg.image_channels
    size = cfg.image_size
    ch = 64
    while size > 4:
        shapes.append((ch, c, 4, 4))  # OIHW
        c, ch, size = ch, ch * 2, size // 2
    return shapes


def init_critic_params(cfg: NetworkConfig, key) -> dict:
    convs = []
    final_spatial = cfg.image_size
    c = cfg.image_channels
    ch = 64
    while final_spatial > 4:
        key, sub = jax.random.split(key)
        convs.append(
            (
                0.02 * jax.random.normal(sub, (ch, c, 4, 4), jnp.float32),
                jnp.zeros((ch,), jnp.float32),
            )
        )
        c, ch = ch, ch * 2
        final_spatial //= 2
    key, sub = jax.random.split(key)
    dense_in = c * final_spatial * final_spatial
    dense = 0.02 * jax.random.normal(sub, (dense_in, 1), jnp.float32)
    return {"convs": convs, "dense": dense}


def critic_apply(params, x):
    """Critic score; plain strided convs + LeakyReLU, scalar output."""
    h = x
    for w, b in params["convs"]:
        h = jax.lax.conv_general_dilated(
            h,
            w,
            window_strides=(2, 2),
            padding=[(1, 1), (1, 1)],
            dimension_numbers=("NCHW", "OIHW", "NCHW"),
        )
        h = leaky_relu(h + b[None, :, None, None])
    h = h.reshape(h.shape[0], -1)
    return h @ params["dense"]


def flatten_params(params) -> list:
    """[(w, b)] → [w0, b0, w1, b1, ...] (the AOT parameter order contract
    shared with the Rust runtime via the artifact manifest)."""
    flat = []
    for w, b in params:
        flat.extend([w, b])
    return flat


def unflatten_params(flat) -> list:
    return [(flat[i], flat[i + 1]) for i in range(0, len(flat), 2)]
