"""Pure-jnp correctness oracles for the reverse-loop deconvolution kernel.

Two independent references:

* :func:`deconv_ref` — fractionally-strided convolution expressed with
  ``lax.conv_general_dilated`` (``lhs_dilation`` = stride, padding
  ``K - 1 - P``, spatially flipped kernel).  This is the textbook
  equivalence of transposed convolution (Dumoulin & Visin, 2016) and is
  what XLA would fuse for a dense deconvolution.

* :func:`deconv_naive` — a literal transcription of the paper's Eq. 1
  (input-space scatter):  ``o = i * S + k - P`` with accumulation over the
  overlapping output regions.  Slow, loop-based, unambiguous.  This is the
  ground truth the Pallas kernel and the Rust substrate are both checked
  against.

Conventions (match PyTorch ``ConvTranspose2d`` and the paper):

* input  ``x``  — ``[N, C_in, I_H, I_W]``
* weight ``w``  — ``[C_in, C_out, K, K]``
* bias   ``b``  — ``[C_out]``
* output ``y``  — ``[N, C_out, O_H, O_W]`` with ``O_H = (I_H-1)*S + K - 2P``
"""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np
from jax import lax


def deconv_output_size(i: int, k: int, s: int, p: int) -> int:
    """Output extent of a transposed convolution (Eq. 1 solved for max o)."""
    return (i - 1) * s + k - 2 * p


def deconv_ref(x, w, b, stride: int, padding: int):
    """Transposed convolution via ``conv_general_dilated`` (XLA-fused oracle)."""
    k = w.shape[2]
    # OIHW with spatial flip: transposed conv == conv with the flipped kernel
    # over the stride-dilated input, padded by K - 1 - P on each side.
    rhs = jnp.flip(w, axis=(2, 3)).transpose(1, 0, 2, 3)
    pad = k - 1 - padding
    y = lax.conv_general_dilated(
        x,
        rhs,
        window_strides=(1, 1),
        padding=[(pad, pad), (pad, pad)],
        lhs_dilation=(stride, stride),
        rhs_dilation=(1, 1),
        dimension_numbers=("NCHW", "OIHW", "NCHW"),
    )
    return y + b[None, :, None, None]


def deconv_naive(x, w, b, stride: int, padding: int):
    """Eq. 1 input-space scatter loop (numpy; the unambiguous ground truth)."""
    x = np.asarray(x)
    w = np.asarray(w)
    b = np.asarray(b)
    n, c_in, i_h, i_w = x.shape
    _, c_out, k, _ = w.shape
    o_h = deconv_output_size(i_h, k, stride, padding)
    o_w = deconv_output_size(i_w, k, stride, padding)
    y = np.zeros((n, c_out, o_h, o_w), dtype=np.float64)
    for bi in range(n):
        for ci in range(c_in):
            for ih in range(i_h):
                for iw in range(i_w):
                    v = x[bi, ci, ih, iw]
                    for kh in range(k):
                        oh = ih * stride + kh - padding
                        if oh < 0 or oh >= o_h:
                            continue
                        for kw in range(k):
                            ow = iw * stride + kw - padding
                            if ow < 0 or ow >= o_w:
                                continue
                            y[bi, :, oh, ow] += v * w[ci, :, kh, kw]
    y += b[None, :, None, None]
    return y.astype(x.dtype)


def stride_hole_offsets(k: int, s: int, p: int) -> np.ndarray:
    """Eq. 3 offsets ``f[k] = mod(S - mod(P - k, S), S)`` (python ``%`` is
    already the non-negative mod the paper's ``mod`` denotes)."""
    return np.array([(s - ((p - kk) % s)) % s for kk in range(k)], dtype=np.int32)


def deconv_reverse_naive(x, w, b, stride: int, padding: int):
    """Reverse-loop deconvolution (the paper's Algorithm 1) in plain numpy.

    Loops over the *output* space with stride-hole skipping (Eqs. 2-4) and
    pre-computed offsets — the direct software model of what the FPGA CUs
    execute.  Used in tests to show Algorithm 1 == Eq. 1 scatter.
    """
    x = np.asarray(x)
    w = np.asarray(w)
    b = np.asarray(b)
    n, c_in, i_h, i_w = x.shape
    _, c_out, k, _ = w.shape
    o_h = deconv_output_size(i_h, k, stride, padding)
    o_w = deconv_output_size(i_w, k, stride, padding)
    f = stride_hole_offsets(k, stride, padding)
    y = np.zeros((n, c_out, o_h, o_w), dtype=np.float64)
    y += b[None, :, None, None]
    for bi in range(n):
        for co in range(c_out):
            for ci in range(c_in):
                for kh in range(k):
                    fh = int(f[kh])
                    for kw in range(k):
                        fw = int(f[kw])
                        for oh in range(fh, o_h, stride):
                            ih, rh = divmod(oh + padding - kh, stride)
                            if rh != 0 or ih < 0 or ih >= i_h:
                                continue
                            for ow in range(fw, o_w, stride):
                                iw, rw = divmod(ow + padding - kw, stride)
                                if rw != 0 or iw < 0 or iw >= i_w:
                                    continue
                                y[bi, co, oh, ow] += (
                                    w[ci, co, kh, kw] * x[bi, ci, ih, iw]
                                )
    return y.astype(x.dtype)


def relu(x):
    return jnp.maximum(x, 0.0)


def leaky_relu(x, alpha: float = 0.2):
    return jnp.where(x >= 0, x, alpha * x)
