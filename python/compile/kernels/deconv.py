"""L1 — Pallas reverse-loop deconvolution kernel (the paper's Algorithm 1).

The paper maps Zhang et al.'s output-space ("reverse looping") deconvolution
onto an FPGA CU array.  This module re-expresses the same three enhancements
for a TPU-style memory hierarchy (see DESIGN.md §Hardware-Adaptation):

1. **Pre-computed modulo offsets** (paper Eq. 3): the stride-hole offsets
   ``f[k]`` depend only on ``k``, so they are folded at *trace time* into
   static strided slices — the kernel body contains zero modulo ops, which
   is strictly stronger than the paper's 2K-entry offset LUT.

2. **Loop interchange / weight reuse**: the ``(k_h, k_w)`` loops are the
   outermost kernel loops (unrolled at trace time).  Each step consumes one
   weight *column* ``w[:, k_h, k_w]`` and touches a contiguous input block —
   one fused multiply-accumulate (``tensordot`` over C_in → MXU) per tap.

3. **Decoupled memory access**: the output feature map is tiled by
   ``BlockSpec`` (one grid step == one CU workload == one ``T×T`` output
   block, the paper's one-shot write), while the input block lives in VMEM
   for the duration of the step (the paper's BRAM tile buffer).  The
   non-sequential access pattern of Eq. 4 is confined to VMEM-local strided
   slices; HBM→VMEM staging is sequential, exactly the paper's DDR→BRAM
   discipline.

Boundary handling: instead of the in-loop bounds guards of Algorithm 1 the
host pads the input once (``plan.pad_l``/``pad_r`` zeros) so that every
input index the kernel computes is in-bounds and out-of-range taps
contribute exactly 0.  This keeps the CU inner loop branch-free — the same
trick the paper's ``loadInputBlock`` plays with BRAM zero-fill.

``interpret=True`` everywhere: the CPU PJRT plugin cannot execute Mosaic
custom-calls; interpret-mode lowers to plain HLO so the AOT artifact runs
on the Rust PJRT CPU client.
"""

from __future__ import annotations

import math
from dataclasses import dataclass
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax
from jax.experimental import pallas as pl

from .ref import deconv_output_size, stride_hole_offsets

# TPU-ish budget used by the planner sanity checks (bytes of VMEM per core).
VMEM_BUDGET_BYTES = 16 * 1024 * 1024
MXU_DIM = 128  # systolic array edge for the utilization estimate


@dataclass(frozen=True)
class TilePlan:
    """Static schedule for one deconvolution layer at one tile size.

    Everything the kernel needs is resolved here, at trace time: the Eq. 3
    offsets, the per-tap slice geometry, and the input padding that makes
    the kernel branch-free.
    """

    i_h: int
    i_w: int
    c_in: int
    c_out: int
    c_blk: int          # output channels per CU workload (MXU width knob)
    k: int
    stride: int
    padding: int
    tile: int           # T_OH == T_OW (paper explores square tiles)
    o_h: int
    o_w: int
    o_h_pad: int        # rounded up to a multiple of `tile`
    o_w_pad: int
    pad_l: int          # input zero-padding (left/top)
    pad_r: int          # input zero-padding (right/bottom)
    offsets: tuple      # f[k] per Eq. 3
    c_k: tuple          # (f[k] + P - k) // S  — static per-tap input shift
    n_rows: tuple       # rows of the input slice consumed per tap

    @property
    def n_tiles_h(self) -> int:
        return self.o_h_pad // self.tile

    @property
    def n_tiles_w(self) -> int:
        return self.o_w_pad // self.tile

    @property
    def i_h_pad(self) -> int:
        return self.i_h + self.pad_l + self.pad_r

    @property
    def i_w_pad(self) -> int:
        return self.i_w + self.pad_l + self.pad_r

    def vmem_footprint_bytes(self, dtype_bytes: int = 4) -> int:
        """VMEM bytes resident during one grid step: padded input block +
        weight block + output block + accumulator classes."""
        x_blk = self.c_in * self.i_h_pad * self.i_w_pad
        w_blk = self.c_in * self.c_blk * self.k * self.k
        o_blk = self.c_blk * self.tile * self.tile
        return dtype_bytes * (x_blk + w_blk + 2 * o_blk)

    def mxu_utilization_estimate(self) -> float:
        """Estimated MXU occupancy of one tap's contraction.

        Each (k_h, k_w) tap is a ``[C_blk, C_in] @ [C_in, tps*tps]``
        matmul on the systolic array: depth ``min(C_in,128)/128`` ×
        result-row occupancy ``min(C_blk,128)/128``.  Used for the
        DESIGN.md real-TPU estimate (interpret-mode wallclock is
        CPU-numpy, not a TPU proxy).
        """
        depth = min(self.c_in, MXU_DIM) / MXU_DIM
        rows = min(self.c_blk, MXU_DIM) / MXU_DIM
        return depth * rows

    def macs(self) -> int:
        """Exact multiply-accumulates of Algorithm 1 over the *valid* output
        (matches the Rust simulator's dense workload model)."""
        total = 0
        for kh in range(self.k):
            n_oh = len(range(self.offsets[kh], self.o_h, self.stride))
            for kw in range(self.k):
                n_ow = len(range(self.offsets[kw], self.o_w, self.stride))
                total += n_oh * n_ow
        # Taps falling outside the input contribute zeros but are still
        # issued by the dense CU schedule; count them all, as the paper's
        # "arithmetic operations of all layers" does.
        return total * self.c_in * self.c_out


def plan_tiles(
    i_h: int,
    i_w: int,
    c_in: int,
    c_out: int,
    k: int,
    stride: int,
    padding: int,
    tile: int,
    c_blk: int | None = None,
) -> TilePlan:
    """Resolve the static schedule (offsets, slices, padding) for a layer."""
    if c_blk is None:
        c_blk = min(c_out, 64)
    while c_out % c_blk != 0:
        c_blk -= 1  # largest divisor of C_out not exceeding the request
    if tile % stride != 0:
        tile += stride - (tile % stride)  # T must cover whole stride classes
    o_h = deconv_output_size(i_h, k, stride, padding)
    o_w = deconv_output_size(i_w, k, stride, padding)
    o_h_pad = math.ceil(o_h / tile) * tile
    o_w_pad = math.ceil(o_w / tile) * tile
    offs = tuple(int(f) for f in stride_hole_offsets(k, stride, padding))
    c_k = tuple((offs[kk] + padding - kk) // stride for kk in range(k))
    n_rows = tuple(
        math.ceil((tile - offs[kk]) / stride) for kk in range(k)
    )
    # Input index for tap k at tile t, row r:  i = t*(T/S) + c_k + r.
    lo = min(c_k)
    n_tiles_h = o_h_pad // tile
    n_tiles_w = o_w_pad // tile
    hi_h = max(
        (n_tiles_h - 1) * (tile // stride) + c_k[kk] + n_rows[kk] - 1
        for kk in range(k)
    )
    hi_w = max(
        (n_tiles_w - 1) * (tile // stride) + c_k[kk] + n_rows[kk] - 1
        for kk in range(k)
    )
    pad_l = max(0, -lo)
    pad_r = max(0, max(hi_h - (i_h - 1), hi_w - (i_w - 1)))
    return TilePlan(
        i_h=i_h, i_w=i_w, c_in=c_in, c_out=c_out, c_blk=c_blk, k=k,
        stride=stride,
        padding=padding, tile=tile, o_h=o_h, o_w=o_w, o_h_pad=o_h_pad,
        o_w_pad=o_w_pad, pad_l=pad_l, pad_r=pad_r, offsets=offs, c_k=c_k,
        n_rows=n_rows,
    )


def _deconv_kernel(x_ref, w_ref, b_ref, o_ref, *, plan: TilePlan):
    """One CU workload: one ``C_blk × T × T`` output block (Algorithm 1).

    Grid: ``(N, C_out/C_blk, n_tiles_h, n_tiles_w)``.  ``x_ref`` holds the
    whole padded input for the batch element (the BRAM-resident tile
    buffer), ``w_ref`` the ``[C_in, C_blk, K, K]`` weight block for this
    channel group.
    """
    t, s, k, cb = plan.tile, plan.stride, plan.k, plan.c_blk
    th = pl.program_id(2)
    tw = pl.program_id(3)
    x = x_ref[0]          # [C_in, I_H_pad, I_W_pad]
    w = w_ref[...]        # [C_in, C_blk, K, K]
    tps = t // s          # input rows spanned by one output tile
    # Stride-class accumulators: output pixels with o ≡ f (mod S) form a
    # compact (T/S)×(T/S) class.  Every tap lands wholly inside one class
    # (f depends only on k — Eq. 3), so Algorithm 1's strided scatter
    # becomes class-local dense adds plus one interleave at the end.
    cls = {}
    for kh in range(k):                     # weight-stationary outer loops
        fh, ckh = plan.offsets[kh], plan.c_k[kh]
        for kw in range(k):
            fw, ckw = plan.offsets[kw], plan.c_k[kw]
            i0 = th * tps + (ckh + plan.pad_l)
            j0 = tw * tps + (ckw + plan.pad_l)
            xs = lax.dynamic_slice(
                x, (0, i0, j0), (plan.c_in, tps, tps)
            )  # sequential BRAM read of the dependent input block
            # one MXU matmul per tap: [C_blk, C_in] @ [C_in, tps*tps]
            tap = jnp.tensordot(w[:, :, kh, kw], xs, axes=(0, 0))
            key = (fh, fw)
            cls[key] = tap if key not in cls else cls[key] + tap
    zero = jnp.zeros((cb, tps, tps), dtype=jnp.float32)
    stacked = jnp.stack(
        [
            jnp.stack([cls.get((rh, rw), zero) for rw in range(s)])
            for rh in range(s)
        ]
    )  # [S, S, C_blk, T/S, T/S]
    # interleave stride classes: y[c, f_h + S*i, f_w + S*j] = cls[f_h,f_w][c,i,j]
    acc = stacked.transpose(2, 3, 0, 4, 1).reshape(cb, t, t)
    bias = b_ref[...]
    o_ref[0] = acc + bias[:, None, None]    # one-shot write of the block


def deconv_pallas(x, w, b, stride: int, padding: int, tile: int,
                  c_blk: int | None = None, interpret: bool = True):
    """Reverse-loop transposed convolution via the Pallas CU-array kernel.

    Args:
      x: ``[N, C_in, I_H, I_W]`` input feature map.
      w: ``[C_in, C_out, K, K]`` deconvolution weights.
      b: ``[C_out]`` bias.
      stride/padding: layer hyper-parameters (square).
      tile: output tiling factor ``T_OH == T_OW`` (the paper's DSE knob).
      c_blk: output channels per grid step (MXU width knob; defaults to
        ``min(C_out, 64)`` rounded down to a divisor of ``C_out``).
      interpret: must stay True for CPU-PJRT execution (Mosaic custom-calls
        only run on real TPUs).

    Returns ``[N, C_out, O_H, O_W]``.
    """
    n, c_in, i_h, i_w = x.shape
    _, c_out, k, _ = w.shape
    plan = plan_tiles(i_h, i_w, c_in, c_out, k, stride, padding, tile, c_blk)
    xp = jnp.pad(
        x,
        ((0, 0), (0, 0), (plan.pad_l, plan.pad_r), (plan.pad_l, plan.pad_r)),
    )
    grid = (n, c_out // plan.c_blk, plan.n_tiles_h, plan.n_tiles_w)
    out = pl.pallas_call(
        partial(_deconv_kernel, plan=plan),
        grid=grid,
        in_specs=[
            pl.BlockSpec(
                (1, c_in, plan.i_h_pad, plan.i_w_pad),
                lambda bi, cg, th, tw: (bi, 0, 0, 0),
            ),
            pl.BlockSpec(
                (c_in, plan.c_blk, k, k),
                lambda bi, cg, th, tw: (0, cg, 0, 0),
            ),
            pl.BlockSpec((plan.c_blk,), lambda bi, cg, th, tw: (cg,)),
        ],
        out_specs=pl.BlockSpec(
            (1, plan.c_blk, plan.tile, plan.tile),
            lambda bi, cg, th, tw: (bi, cg, th, tw),
        ),
        out_shape=jax.ShapeDtypeStruct(
            (n, c_out, plan.o_h_pad, plan.o_w_pad), jnp.float32
        ),
        interpret=interpret,
    )(xp, w, b)
    return out[:, :, : plan.o_h, : plan.o_w]
