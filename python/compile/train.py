"""WGAN-GP trainer (Gulrajani et al., 2017) — build-time only.

Trains the Fig. 4 generators on the synthetic corpora so the AOT artifacts
carry *learned* weights (the sparsity experiments of Fig. 6 need weights
whose magnitudes are meaningful to prune).  Python never runs at serving
time; this module is invoked once by ``aot.py`` / ``make artifacts``.

Losses: critic  E[D(fake)] − E[D(real)] + λ·GP,  generator  −E[D(fake)],
λ = 10, n_critic = 5, Adam(α=1e-4, β₁=0.5, β₂=0.9) — hand-rolled Adam
(the image has no optax).
"""

from __future__ import annotations

import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from . import data
from .model import (
    NetworkConfig,
    critic_apply,
    generator_apply,
    init_critic_params,
    init_generator_params,
)

GP_LAMBDA = 10.0
N_CRITIC = 5
ADAM = dict(lr=1e-4, b1=0.5, b2=0.9, eps=1e-8)


# ----------------------------------------------------------------- optimizer
def adam_init(params):
    zeros = jax.tree_util.tree_map(jnp.zeros_like, params)
    return {"m": zeros, "v": jax.tree_util.tree_map(jnp.zeros_like, params),
            "t": jnp.zeros((), jnp.int32)}


def adam_update(params, grads, state):
    t = state["t"] + 1
    b1, b2, lr, eps = ADAM["b1"], ADAM["b2"], ADAM["lr"], ADAM["eps"]
    m = jax.tree_util.tree_map(
        lambda m_, g: b1 * m_ + (1 - b1) * g, state["m"], grads
    )
    v = jax.tree_util.tree_map(
        lambda v_, g: b2 * v_ + (1 - b2) * g * g, state["v"], grads
    )
    mhat_scale = 1.0 / (1 - b1 ** t.astype(jnp.float32))
    vhat_scale = 1.0 / (1 - b2 ** t.astype(jnp.float32))
    new_params = jax.tree_util.tree_map(
        lambda p, m_, v_: p
        - lr * (m_ * mhat_scale) / (jnp.sqrt(v_ * vhat_scale) + eps),
        params,
        m,
        v,
    )
    return new_params, {"m": m, "v": v, "t": t}


# ----------------------------------------------------------------- losses
def gradient_penalty(c_params, real, fake, key):
    eps = jax.random.uniform(key, (real.shape[0], 1, 1, 1))
    inter = eps * real + (1 - eps) * fake

    def score_sum(x):
        return critic_apply(c_params, x).sum()

    grads = jax.grad(score_sum)(inter)
    norms = jnp.sqrt(jnp.sum(grads**2, axis=(1, 2, 3)) + 1e-12)
    return jnp.mean((norms - 1.0) ** 2)


def make_train_steps(cfg: NetworkConfig):
    """Build jitted critic/generator update steps for this network."""

    def critic_loss(c_params, g_params, real, z, key):
        fake = generator_apply(g_params, z, cfg, use_pallas=False)
        loss = (
            critic_apply(c_params, fake).mean()
            - critic_apply(c_params, real).mean()
            + GP_LAMBDA * gradient_penalty(c_params, real, fake, key)
        )
        return loss

    def gen_loss(g_params, c_params, z):
        fake = generator_apply(g_params, z, cfg, use_pallas=False)
        return -critic_apply(c_params, fake).mean()

    @jax.jit
    def critic_step(c_params, c_opt, g_params, real, z, key):
        loss, grads = jax.value_and_grad(critic_loss)(
            c_params, g_params, real, z, key
        )
        c_params, c_opt = adam_update(c_params, grads, c_opt)
        return c_params, c_opt, loss

    @jax.jit
    def gen_step(g_params, g_opt, c_params, z):
        loss, grads = jax.value_and_grad(gen_loss)(g_params, c_params, z)
        g_params, g_opt = adam_update(g_params, grads, g_opt)
        return g_params, g_opt, loss

    return critic_step, gen_step


def train_wgan_gp(
    cfg: NetworkConfig,
    steps: int,
    batch: int,
    corpus_size: int = 512,
    seed: int = 0,
    log_every: int = 10,
    verbose: bool = True,
    corpus=None,
):
    """Train; returns (generator params, training log dict).

    ``corpus`` overrides the synthetic dataset (used by tests with tiny
    custom networks); by default it is generated from ``cfg.name``.
    """
    key = jax.random.PRNGKey(seed)
    key, gk, ck = jax.random.split(key, 3)
    g_params = init_generator_params(cfg, gk)
    c_params = init_critic_params(cfg, ck)
    g_opt = adam_init(g_params)
    c_opt = adam_init(c_params)
    if corpus is None:
        corpus = data.corpus_for(cfg.name, corpus_size, seed=seed)
    corpus_size = len(corpus)
    rng = np.random.default_rng(seed)
    critic_step, gen_step = make_train_steps(cfg)

    log = {"network": cfg.name, "steps": steps, "batch": batch,
           "corpus_size": corpus_size, "history": []}
    t0 = time.time()
    for step in range(steps):
        c_losses = []
        for _ in range(N_CRITIC):
            idx = rng.integers(0, corpus_size, batch)
            real = jnp.asarray(corpus[idx])
            key, zk, gpk = jax.random.split(key, 3)
            z = jax.random.normal(zk, (batch, cfg.z_dim))
            c_params, c_opt, c_loss = critic_step(
                c_params, c_opt, g_params, real, z, gpk
            )
            c_losses.append(float(c_loss))
        key, zk = jax.random.split(key)
        z = jax.random.normal(zk, (batch, cfg.z_dim))
        g_params, g_opt, g_loss = gen_step(g_params, g_opt, c_params, z)
        if step % log_every == 0 or step == steps - 1:
            entry = {
                "step": step,
                "critic_loss": float(np.mean(c_losses)),
                "gen_loss": float(g_loss),
                "wall_s": round(time.time() - t0, 2),
            }
            log["history"].append(entry)
            if verbose:
                print(
                    f"[{cfg.name}] step {step:4d}  "
                    f"critic {entry['critic_loss']:+.4f}  "
                    f"gen {entry['gen_loss']:+.4f}  "
                    f"({entry['wall_s']:.1f}s)",
                    flush=True,
                )
    log["total_wall_s"] = round(time.time() - t0, 2)
    return g_params, log


def save_log(log: dict, path: str):
    with open(path, "w") as f:
        json.dump(log, f, indent=1)
