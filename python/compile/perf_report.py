"""L1/L2 performance report (EXPERIMENTS.md §Perf).

interpret=True wallclock is CPU-numpy, not a TPU proxy, so the L1 kernel
is profiled *structurally*: per-layer VMEM footprint and MXU-occupancy
estimates of the chosen BlockSpec schedule, swept over the `c_blk`
(output-channels-per-grid-step) knob.  Also dumps L2 HLO statistics
(instruction counts, fusion check) for the lowered generators.

Usage:  cd python && python -m compile.perf_report
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .kernels.deconv import plan_tiles, VMEM_BUDGET_BYTES
from .model import CONFIGS, flatten_params, generator_apply, \
    init_generator_params, unflatten_params


def l1_report():
    print("== L1: Pallas kernel schedule (VMEM footprint / MXU estimate) ==")
    print(f"{'net':<8}{'layer':<7}{'c_blk':>6}{'grid':>8}{'VMEM KiB':>10}"
          f"{'MXU est':>9}  fits")
    for name, mk in CONFIGS.items():
        cfg = mk()
        for i, l in enumerate(cfg.layers):
            for c_blk in (16, 64, 128):
                plan = plan_tiles(l.i_h, l.i_h, l.c_in, l.c_out, l.k,
                                  l.stride, l.padding, cfg.tile,
                                  min(c_blk, l.c_out))
                grid = (l.c_out // plan.c_blk) * plan.n_tiles_h \
                    * plan.n_tiles_w
                vmem = plan.vmem_footprint_bytes()
                print(f"{name:<8}L{i:<6}{plan.c_blk:>6}{grid:>8}"
                      f"{vmem/1024:>10.1f}"
                      f"{plan.mxu_utilization_estimate():>9.3f}"
                      f"  {'yes' if vmem < VMEM_BUDGET_BYTES else 'NO'}")


def l2_report():
    print("\n== L2: lowered-HLO statistics (fusion / recompute check) ==")
    for name, mk in CONFIGS.items():
        cfg = mk()
        params = init_generator_params(cfg, jax.random.PRNGKey(0))

        def fwd(z, *flat):
            return (generator_apply(unflatten_params(list(flat)), z, cfg,
                                    use_pallas=True),)

        z = jax.ShapeDtypeStruct((1, cfg.z_dim), jnp.float32)
        specs = [jax.ShapeDtypeStruct(p.shape, jnp.float32)
                 for p in flatten_params(params)]
        lowered = jax.jit(fwd).lower(z, *specs)
        hlo = lowered.compile().as_text()
        lines = hlo.splitlines()
        fusions = sum("fusion" in ln for ln in lines)
        convs = sum("convolution" in ln for ln in lines)
        dots = sum(" dot(" in ln or " dot." in ln for ln in lines)
        whiles = sum("while" in ln for ln in lines)
        print(f"{name}: compiled HLO {len(lines)} lines — "
              f"{fusions} fusion refs, {convs} convolutions, "
              f"{dots} dots, {whiles} while refs")


if __name__ == "__main__":
    l1_report()
    l2_report()
