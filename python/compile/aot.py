"""AOT exporter — the single build-time entry point (``make artifacts``).

Produces everything the Rust runtime consumes, then Python exits the
picture (it is never on the request path):

* ``artifacts/<net>_gen_b<N>.hlo.txt``   — generator forward pass (Pallas
  reverse-loop deconv kernels, interpret-lowered) for each serving batch
  size.  Weights are HLO *parameters* so Rust can feed pruned tensors.
* ``artifacts/<net>_layer<i>_b<N>.hlo.txt`` — single-layer executables for
  the per-layer Table II measurements.
* ``artifacts/weights/<net>_l<i>_{w,b}.npy`` — trained WGAN-GP weights.
* ``artifacts/<net>_truth.npy``          — ground-truth sample batch
  (P_g draws) for the Rust-side MMD of Fig. 6b.
* ``artifacts/train_log_<net>.json``     — training loss curves
  (EXPERIMENTS.md end-to-end record).
* ``artifacts/manifest.json``            — the Rust/Python contract:
  shapes, parameter order, tile factors, op counts, artifact paths.

Interchange format is **HLO text**, not a serialized ``HloModuleProto``:
jax ≥ 0.5 emits protos with 64-bit instruction ids which the ``xla``
crate's xla_extension 0.5.1 rejects; the text parser reassigns ids (see
/opt/xla-example/README.md).
"""

from __future__ import annotations

import argparse
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import train as train_mod
from .data import corpus_for
from .model import (
    CONFIGS,
    NetworkConfig,
    flatten_params,
    generator_apply,
    generator_layer_apply,
    unflatten_params,
)

# Serving batch sizes baked into the artifact set; the Rust dynamic batcher
# buckets requests into the largest exported size (vLLM-style bucketing).
BATCH_SIZES = {"mnist": (1, 4, 8), "celeba": (1, 4)}
TRUTH_SAMPLES = 256


def to_hlo_text(lowered) -> str:
    """StableHLO → XlaComputation → HLO text (the 0.5.1-safe interchange)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def export_generator(cfg: NetworkConfig, params, batch: int, out_dir: str):
    """Lower the full generator (z + flat weights → images) to HLO text."""

    def fwd(z, *flat):
        return (generator_apply(unflatten_params(list(flat)), z, cfg,
                                use_pallas=True),)

    z_spec = jax.ShapeDtypeStruct((batch, cfg.z_dim), jnp.float32)
    w_specs = [
        jax.ShapeDtypeStruct(p.shape, jnp.float32)
        for p in flatten_params(params)
    ]
    lowered = jax.jit(fwd).lower(z_spec, *w_specs)
    text = to_hlo_text(lowered)
    path = os.path.join(out_dir, f"{cfg.name}_gen_b{batch}.hlo.txt")
    with open(path, "w") as f:
        f.write(text)
    return os.path.basename(path), len(text)


def export_layer(cfg: NetworkConfig, li: int, batch: int, out_dir: str):
    """Lower one deconv layer (x, w, b → activation) to HLO text."""
    layer = cfg.layers[li]
    activation = "tanh" if li == len(cfg.layers) - 1 else "relu"

    def fwd(x, w, b):
        return (
            generator_layer_apply(
                x, w, b, layer, cfg.tile, use_pallas=True,
                activation=activation,
            ),
        )

    x_spec = jax.ShapeDtypeStruct(
        (batch, layer.c_in, layer.i_h, layer.i_h), jnp.float32
    )
    w_spec = jax.ShapeDtypeStruct(layer.weight_shape(), jnp.float32)
    b_spec = jax.ShapeDtypeStruct((layer.c_out,), jnp.float32)
    lowered = jax.jit(fwd).lower(x_spec, w_spec, b_spec)
    text = to_hlo_text(lowered)
    path = os.path.join(out_dir, f"{cfg.name}_layer{li}_b{batch}.hlo.txt")
    with open(path, "w") as f:
        f.write(text)
    return os.path.basename(path), len(text)


def export_network(cfg: NetworkConfig, steps: int, batch: int,
                   out_dir: str, seed: int = 0) -> dict:
    """Train + export one network; returns its manifest fragment."""
    print(f"=== {cfg.name}: training WGAN-GP for {steps} steps ===",
          flush=True)
    params, log = train_mod.train_wgan_gp(cfg, steps=steps, batch=batch,
                                          seed=seed)
    train_mod.save_log(log, os.path.join(out_dir,
                                         f"train_log_{cfg.name}.json"))

    wdir = os.path.join(out_dir, "weights")
    os.makedirs(wdir, exist_ok=True)
    weight_files = []
    for i, (w, b) in enumerate(params):
        wp = os.path.join(wdir, f"{cfg.name}_l{i}_w.npy")
        bp = os.path.join(wdir, f"{cfg.name}_l{i}_b.npy")
        np.save(wp, np.asarray(w))
        np.save(bp, np.asarray(b))
        weight_files.append(
            {"w": os.path.relpath(wp, out_dir),
             "b": os.path.relpath(bp, out_dir)}
        )

    truth = corpus_for(cfg.name, TRUTH_SAMPLES, seed=seed + 1)
    truth_path = os.path.join(out_dir, f"{cfg.name}_truth.npy")
    np.save(truth_path, truth)

    generators = {}
    for bs in BATCH_SIZES[cfg.name]:
        name, size = export_generator(cfg, params, bs, out_dir)
        print(f"  gen  b{bs}: {name} ({size/1e6:.2f} MB)", flush=True)
        generators[str(bs)] = name
    layer_artifacts = []
    for li in range(len(cfg.layers)):
        name, size = export_layer(cfg, li, 1, out_dir)
        print(f"  layer {li}: {name} ({size/1e6:.2f} MB)", flush=True)
        layer_artifacts.append(name)

    return {
        "name": cfg.name,
        "z_dim": cfg.z_dim,
        "tile": cfg.tile,
        "image_size": cfg.image_size,
        "image_channels": cfg.image_channels,
        "batch_sizes": list(BATCH_SIZES[cfg.name]),
        "generators": generators,
        "layer_artifacts": layer_artifacts,
        "weights": weight_files,
        "truth": os.path.basename(truth_path),
        "train_log": f"train_log_{cfg.name}.json",
        "layers": [
            {
                "c_in": l.c_in,
                "c_out": l.c_out,
                "k": l.k,
                "stride": l.stride,
                "padding": l.padding,
                "i_h": l.i_h,
                "o_h": l.o_h,
                "ops": l.ops(),
                "macs": l.macs(),
            }
            for l in cfg.layers
        ],
        # Parameter order contract: z, then w0, b0, w1, b1, ...
        "param_order": ["z"]
        + [f"{t}{i}" for i in range(len(cfg.layers)) for t in ("w", "b")],
    }


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts",
                    help="artifact output directory")
    ap.add_argument("--steps-mnist", type=int,
                    default=int(os.environ.get("EDGEDCNN_STEPS_MNIST", 120)))
    ap.add_argument("--steps-celeba", type=int,
                    default=int(os.environ.get("EDGEDCNN_STEPS_CELEBA", 40)))
    ap.add_argument("--batch-mnist", type=int, default=32)
    ap.add_argument("--batch-celeba", type=int, default=8)
    ap.add_argument("--networks", default="mnist,celeba")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    out_dir = args.out
    os.makedirs(out_dir, exist_ok=True)
    # merge with an existing manifest so re-exporting one network (e.g.
    # extended training) preserves the others
    manifest = {"version": 1, "networks": {}}
    manifest_path = os.path.join(out_dir, "manifest.json")
    if os.path.exists(manifest_path):
        with open(manifest_path) as f:
            prev = json.load(f)
        if prev.get("version") == 1:
            manifest["networks"].update(prev.get("networks", {}))
    for name in args.networks.split(","):
        cfg = CONFIGS[name]()
        steps = args.steps_mnist if name == "mnist" else args.steps_celeba
        batch = args.batch_mnist if name == "mnist" else args.batch_celeba
        manifest["networks"][name] = export_network(
            cfg, steps=steps, batch=batch, out_dir=out_dir, seed=args.seed
        )
    with open(os.path.join(out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    print(f"manifest written to {out_dir}/manifest.json", flush=True)


if __name__ == "__main__":
    main()
