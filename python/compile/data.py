"""Synthetic stand-ins for the MNIST and CelebA corpora.

The image does not ship the real datasets (and the build must run
offline), so we substitute procedurally generated corpora with the same
shapes and enough structure that (a) WGAN-GP training has a non-trivial
target distribution, and (b) MMD-to-ground-truth degrades monotonically as
the generator is pruned (the property Fig. 6b measures).  The substitution
is documented in DESIGN.md.

* ``mnist_like`` — 28×28×1 seven-segment-style digits with random
  per-sample geometry jitter, stroke thickness, and smoothing.
* ``celeba_like`` — 64×64×3 procedural "blob faces": background gradient,
  skin-tone face ellipse, hair band, eyes, mouth, all jittered per sample.

All images are float32 in [-1, 1], NCHW.
"""

from __future__ import annotations

import numpy as np

# Seven-segment layout on a [0,1]² canvas: (x0, y0, x1, y1) per segment.
_SEGMENTS = {
    "top": (0.25, 0.15, 0.75, 0.22),
    "mid": (0.25, 0.47, 0.75, 0.54),
    "bot": (0.25, 0.80, 0.75, 0.87),
    "tl": (0.22, 0.15, 0.32, 0.52),
    "tr": (0.68, 0.15, 0.78, 0.52),
    "bl": (0.22, 0.50, 0.32, 0.87),
    "br": (0.68, 0.50, 0.78, 0.87),
}

_DIGIT_SEGMENTS = {
    0: ("top", "tl", "tr", "bl", "br", "bot"),
    1: ("tr", "br"),
    2: ("top", "tr", "mid", "bl", "bot"),
    3: ("top", "tr", "mid", "br", "bot"),
    4: ("tl", "tr", "mid", "br"),
    5: ("top", "tl", "mid", "br", "bot"),
    6: ("top", "tl", "mid", "bl", "br", "bot"),
    7: ("top", "tr", "br"),
    8: ("top", "tl", "tr", "mid", "bl", "br", "bot"),
    9: ("top", "tl", "tr", "mid", "br", "bot"),
}


def _smooth(img: np.ndarray, passes: int = 1) -> np.ndarray:
    """Cheap separable box blur (anti-aliases the hard segment edges)."""
    for _ in range(passes):
        img = (
            img
            + np.roll(img, 1, -1)
            + np.roll(img, -1, -1)
            + np.roll(img, 1, -2)
            + np.roll(img, -1, -2)
        ) / 5.0
    return img


def mnist_like(n: int, seed: int = 0, size: int = 28) -> np.ndarray:
    """Procedural digit corpus, ``[n, 1, size, size]`` float32 in [-1, 1]."""
    rng = np.random.default_rng(seed)
    out = np.zeros((n, 1, size, size), dtype=np.float32)
    ys, xs = np.mgrid[0:size, 0:size]
    ysf = (ys + 0.5) / size
    xsf = (xs + 0.5) / size
    for i in range(n):
        digit = int(rng.integers(0, 10))
        dx, dy = rng.normal(0, 0.03, 2)  # per-sample translation jitter
        thick = rng.uniform(0.8, 1.6)    # stroke thickness jitter
        img = np.zeros((size, size), dtype=np.float32)
        for seg in _DIGIT_SEGMENTS[digit]:
            x0, y0, x1, y1 = _SEGMENTS[seg]
            cx0, cy0 = x0 + dx, y0 + dy
            cx1, cy1 = x1 + dx, y1 + dy
            # widen thin dimension by the thickness factor
            w2 = (cx1 - cx0) / 2 * (thick if (cx1 - cx0) < 0.2 else 1.0)
            h2 = (cy1 - cy0) / 2 * (thick if (cy1 - cy0) < 0.2 else 1.0)
            mx, my = (cx0 + cx1) / 2, (cy0 + cy1) / 2
            mask = (np.abs(xsf - mx) <= w2) & (np.abs(ysf - my) <= h2)
            img[mask] = 1.0
        img = _smooth(img, passes=2)
        img += rng.normal(0, 0.02, img.shape).astype(np.float32)
        out[i, 0] = np.clip(img, 0.0, 1.0)
    return (out * 2.0 - 1.0).astype(np.float32)


def celeba_like(n: int, seed: int = 0, size: int = 64) -> np.ndarray:
    """Procedural face corpus, ``[n, 3, size, size]`` float32 in [-1, 1]."""
    rng = np.random.default_rng(seed)
    out = np.zeros((n, 3, size, size), dtype=np.float32)
    ys, xs = np.mgrid[0:size, 0:size]
    ysf = (ys + 0.5) / size
    xsf = (xs + 0.5) / size
    for i in range(n):
        img = np.zeros((3, size, size), dtype=np.float32)
        # background: vertical gradient between two random muted colors
        c0 = rng.uniform(0.2, 0.8, 3)
        c1 = rng.uniform(0.2, 0.8, 3)
        for ch in range(3):
            img[ch] = c0[ch] + (c1[ch] - c0[ch]) * ysf
        # face ellipse: skin tone with jitter
        fx, fy = 0.5 + rng.normal(0, 0.03), 0.55 + rng.normal(0, 0.03)
        fa, fb = rng.uniform(0.24, 0.3), rng.uniform(0.3, 0.38)
        skin = np.array([0.85, 0.65, 0.5]) + rng.normal(0, 0.04, 3)
        face = ((xsf - fx) / fa) ** 2 + ((ysf - fy) / fb) ** 2 <= 1.0
        for ch in range(3):
            img[ch][face] = skin[ch]
        # hair: dark band across the top of the face ellipse
        hair_color = rng.uniform(0.05, 0.35, 3) * rng.uniform(0.3, 1.0)
        hair = face & (ysf < fy - 0.4 * fb + rng.normal(0, 0.01))
        for ch in range(3):
            img[ch][hair] = hair_color[ch]
        # eyes: two dark ellipses
        for ex in (fx - 0.4 * fa, fx + 0.4 * fa):
            eye = ((xsf - ex) / 0.05) ** 2 + (
                (ysf - (fy - 0.1 * fb)) / 0.035
            ) ** 2 <= 1.0
            for ch in range(3):
                img[ch][eye] = 0.1
        # mouth: reddish box
        mouth = (np.abs(xsf - fx) <= 0.1) & (
            np.abs(ysf - (fy + 0.5 * fb)) <= 0.025
        )
        img[0][mouth] = 0.7
        img[1][mouth] = 0.2
        img[2][mouth] = 0.25
        img = _smooth(img, passes=1)
        img += rng.normal(0, 0.015, img.shape).astype(np.float32)
        out[i] = np.clip(img, 0.0, 1.0)
    return (out * 2.0 - 1.0).astype(np.float32)


def corpus_for(name: str, n: int, seed: int = 0) -> np.ndarray:
    if name == "mnist":
        return mnist_like(n, seed)
    if name == "celeba":
        return celeba_like(n, seed)
    raise ValueError(f"unknown corpus {name!r}")
