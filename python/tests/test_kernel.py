"""L1 correctness: Pallas reverse-loop kernel vs the pure-jnp oracles.

This is the core numeric signal of the build: Algorithm 1 (Pallas) ==
Eq. 1 scatter (naive numpy) == fused XLA transposed convolution, across
layer geometries, strides, paddings and tile factors — including every
layer shape of the paper's two networks (Fig. 4).
"""

import numpy as np
import pytest

import jax.numpy as jnp
from hypothesis import given, settings, strategies as st

from compile.kernels.deconv import deconv_pallas, plan_tiles
from compile.kernels.ref import (
    deconv_naive,
    deconv_output_size,
    deconv_ref,
    deconv_reverse_naive,
    stride_hole_offsets,
)

RNG = np.random.default_rng(1234)


def rand_case(n, c_in, c_out, k, s, p, i_h):
    x = RNG.normal(size=(n, c_in, i_h, i_h)).astype(np.float32)
    w = RNG.normal(size=(c_in, c_out, k, k)).astype(np.float32)
    b = RNG.normal(size=(c_out,)).astype(np.float32)
    return x, w, b


# ----------------------------------------------------- oracle cross-checks
@pytest.mark.parametrize(
    "c_in,c_out,k,s,p,i_h",
    [
        (3, 5, 4, 2, 1, 5),
        (2, 3, 7, 1, 0, 1),
        (4, 2, 3, 3, 1, 4),
        (1, 1, 5, 2, 2, 6),
        (2, 4, 2, 2, 0, 3),
    ],
)
def test_ref_equals_naive(c_in, c_out, k, s, p, i_h):
    x, w, b = rand_case(2, c_in, c_out, k, s, p, i_h)
    ref = np.asarray(deconv_ref(jnp.array(x), jnp.array(w), jnp.array(b), s, p))
    naive = deconv_naive(x, w, b, s, p)
    np.testing.assert_allclose(ref, naive, rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize(
    "c_in,c_out,k,s,p,i_h",
    [
        (3, 5, 4, 2, 1, 5),
        (2, 3, 7, 1, 0, 1),
        (4, 2, 3, 3, 1, 4),
        (1, 1, 5, 2, 2, 6),
    ],
)
def test_reverse_loop_equals_naive(c_in, c_out, k, s, p, i_h):
    """Algorithm 1 (output-space, stride-hole skipping) == Eq. 1 scatter."""
    x, w, b = rand_case(1, c_in, c_out, k, s, p, i_h)
    np.testing.assert_allclose(
        deconv_reverse_naive(x, w, b, s, p),
        deconv_naive(x, w, b, s, p),
        rtol=1e-4,
        atol=1e-4,
    )


# ----------------------------------------------------- Eq. 3 offsets
@pytest.mark.parametrize("k,s,p", [(4, 2, 1), (7, 1, 0), (3, 3, 1), (5, 2, 2)])
def test_offsets_in_range_and_alignment(k, s, p):
    f = stride_hole_offsets(k, s, p)
    assert f.shape == (k,)
    assert (f >= 0).all() and (f < s).all()
    for kk in range(k):
        # Eq. 4: the offset must make (o + P - k) divisible by S at o = f
        assert (f[kk] + p - kk) % s == 0


def test_offsets_match_paper_formula_bruteforce():
    """f[k] is the smallest o ≥ 0 with (o + P - k) ≡ 0 (mod S)."""
    for s in range(1, 5):
        for p in range(0, 4):
            for k in range(1, 8):
                f = stride_hole_offsets(k, s, p)
                for kk in range(k):
                    brute = next(
                        o for o in range(s) if (o + p - kk) % s == 0
                    )
                    assert f[kk] == brute, (k, s, p, kk)


# ----------------------------------------------------- pallas vs oracle
PAPER_LAYERS = [
    # (c_in, c_out, k, s, p, i_h, tile) — all layers of both Fig. 4 nets
    (100, 128, 7, 1, 0, 1, 12),   # mnist L1
    (128, 64, 4, 2, 1, 7, 12),    # mnist L2
    (64, 1, 4, 2, 1, 14, 12),     # mnist L3
    (100, 512, 4, 1, 0, 1, 24),   # celeba L1
    (512, 256, 4, 2, 1, 4, 24),   # celeba L2
    (256, 128, 4, 2, 1, 8, 24),   # celeba L3
    (128, 64, 4, 2, 1, 16, 24),   # celeba L4
    (64, 3, 4, 2, 1, 32, 24),     # celeba L5
]


@pytest.mark.parametrize("c_in,c_out,k,s,p,i_h,tile", PAPER_LAYERS)
def test_pallas_matches_ref_on_paper_layers(c_in, c_out, k, s, p, i_h, tile):
    x, w, b = rand_case(1, c_in, c_out, k, s, p, i_h)
    got = np.asarray(
        deconv_pallas(jnp.array(x), jnp.array(w), jnp.array(b), s, p, tile)
    )
    ref = np.asarray(deconv_ref(jnp.array(x), jnp.array(w), jnp.array(b), s, p))
    np.testing.assert_allclose(got, ref, rtol=2e-3, atol=2e-3)


@pytest.mark.parametrize("tile", [4, 6, 8, 12, 24])
def test_pallas_tile_factor_invariance(tile):
    """The DSE knob T_OH must never change the numerics."""
    x, w, b = rand_case(2, 3, 4, 4, 2, 1, 8)
    base = deconv_naive(x, w, b, 2, 1)
    got = np.asarray(
        deconv_pallas(jnp.array(x), jnp.array(w), jnp.array(b), 2, 1, tile)
    )
    np.testing.assert_allclose(got, base, rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("c_blk", [1, 2, 4, 8])
def test_pallas_channel_block_invariance(c_blk):
    x, w, b = rand_case(1, 3, 8, 4, 2, 1, 5)
    base = deconv_naive(x, w, b, 2, 1)
    got = np.asarray(
        deconv_pallas(
            jnp.array(x), jnp.array(w), jnp.array(b), 2, 1, 8, c_blk=c_blk
        )
    )
    np.testing.assert_allclose(got, base, rtol=1e-4, atol=1e-4)


@settings(max_examples=25, deadline=None)
@given(
    c_in=st.integers(1, 6),
    c_out=st.integers(1, 6),
    k=st.integers(1, 5),
    s=st.integers(1, 3),
    i_h=st.integers(1, 6),
    n=st.integers(1, 2),
    tile=st.integers(2, 10),
    data=st.data(),
)
def test_pallas_matches_naive_hypothesis(c_in, c_out, k, s, i_h, n, tile, data):
    """Property sweep over the kernel's shape space (hypothesis)."""
    p = data.draw(st.integers(0, max(0, k - 1)))
    if deconv_output_size(i_h, k, s, p) <= 0:
        return
    x, w, b = rand_case(n, c_in, c_out, k, s, p, i_h)
    got = np.asarray(
        deconv_pallas(jnp.array(x), jnp.array(w), jnp.array(b), s, p, tile)
    )
    np.testing.assert_allclose(
        got, deconv_naive(x, w, b, s, p), rtol=1e-3, atol=1e-3
    )


# ----------------------------------------------------- plan invariants
@settings(max_examples=40, deadline=None)
@given(
    c_in=st.integers(1, 64),
    c_out=st.integers(1, 64),
    k=st.integers(1, 7),
    s=st.integers(1, 4),
    i_h=st.integers(1, 32),
    tile=st.integers(2, 32),
    data=st.data(),
)
def test_plan_invariants(c_in, c_out, k, s, i_h, tile, data):
    p = data.draw(st.integers(0, max(0, k - 1)))
    if deconv_output_size(i_h, k, s, p) <= 0:
        return
    plan = plan_tiles(i_h, i_h, c_in, c_out, k, s, p, tile)
    assert plan.tile % plan.stride == 0
    assert plan.o_h_pad % plan.tile == 0
    assert plan.o_h_pad >= plan.o_h
    assert plan.pad_l >= 0 and plan.pad_r >= 0
    assert plan.c_out % plan.c_blk == 0
    # every tap's input slice stays inside the padded input
    tps = plan.tile // plan.stride
    for kk in range(k):
        i_lo = plan.c_k[kk] + plan.pad_l
        i_hi = (plan.n_tiles_h - 1) * tps + plan.c_k[kk] + plan.pad_l + tps - 1
        assert i_lo >= 0
        assert i_hi < plan.i_h_pad
    assert plan.macs() > 0
    assert 0.0 < plan.mxu_utilization_estimate() <= 1.0


def test_plan_vmem_budget_on_paper_layers():
    """Every paper layer's schedule must fit the 16 MiB VMEM budget."""
    for c_in, c_out, k, s, p, i_h, tile in PAPER_LAYERS:
        plan = plan_tiles(i_h, i_h, c_in, c_out, k, s, p, tile)
        assert plan.vmem_footprint_bytes() < 16 * 1024 * 1024


def test_zero_weights_give_bias_only():
    x = RNG.normal(size=(1, 3, 4, 4)).astype(np.float32)
    w = np.zeros((3, 2, 4, 4), dtype=np.float32)
    b = np.array([1.5, -0.5], dtype=np.float32)
    out = np.asarray(
        deconv_pallas(jnp.array(x), jnp.array(w), jnp.array(b), 2, 1, 8)
    )
    assert np.allclose(out[:, 0], 1.5) and np.allclose(out[:, 1], -0.5)


def test_output_size_formula():
    # classic identities
    assert deconv_output_size(1, 7, 1, 0) == 7
    assert deconv_output_size(7, 4, 2, 1) == 14
    assert deconv_output_size(14, 4, 2, 1) == 28
    assert deconv_output_size(4, 4, 2, 1) == 8
    assert deconv_output_size(32, 4, 2, 1) == 64
