"""L2 correctness: generator/critic shapes, pallas-vs-ref path agreement,
op accounting used by the Table II GOps numerators."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile.model import (
    CONFIGS,
    celeba_config,
    critic_apply,
    flatten_params,
    generator_apply,
    init_critic_params,
    init_generator_params,
    mnist_config,
    unflatten_params,
)


def test_mnist_geometry():
    cfg = mnist_config()
    assert [l.o_h for l in cfg.layers] == [7, 14, 28]
    assert cfg.layers[-1].c_out == 1
    assert cfg.image_size == 28 and cfg.tile == 12


def test_celeba_geometry():
    cfg = celeba_config()
    assert [l.o_h for l in cfg.layers] == [4, 8, 16, 32, 64]
    assert cfg.layers[-1].c_out == 3
    assert cfg.image_size == 64 and cfg.tile == 24


@pytest.mark.parametrize("name", ["mnist", "celeba"])
def test_layer_chaining(name):
    """Each layer's output extent/channels must feed the next layer."""
    cfg = CONFIGS[name]()
    assert cfg.layers[0].c_in == cfg.z_dim
    for prev, nxt in zip(cfg.layers, cfg.layers[1:]):
        assert prev.o_h == nxt.i_h
        assert prev.c_out == nxt.c_in
    assert cfg.layers[-1].o_h == cfg.image_size
    assert cfg.layers[-1].c_out == cfg.image_channels


@pytest.mark.parametrize("name", ["mnist", "celeba"])
def test_generator_output_shape_and_range(name):
    cfg = CONFIGS[name]()
    params = init_generator_params(cfg, jax.random.PRNGKey(0))
    z = jax.random.normal(jax.random.PRNGKey(1), (2, cfg.z_dim))
    img = generator_apply(params, z, cfg, use_pallas=False)
    assert img.shape == (2, cfg.image_channels, cfg.image_size, cfg.image_size)
    assert float(jnp.max(jnp.abs(img))) <= 1.0  # tanh range


@pytest.mark.parametrize("name", ["mnist", "celeba"])
def test_pallas_path_matches_ref_path(name):
    """The AOT (Pallas) forward pass == the training (fused XLA) pass."""
    cfg = CONFIGS[name]()
    params = init_generator_params(cfg, jax.random.PRNGKey(2))
    z = jax.random.normal(jax.random.PRNGKey(3), (2, cfg.z_dim))
    a = np.asarray(generator_apply(params, z, cfg, use_pallas=True))
    b = np.asarray(generator_apply(params, z, cfg, use_pallas=False))
    np.testing.assert_allclose(a, b, rtol=1e-4, atol=1e-5)


@pytest.mark.parametrize("name", ["mnist", "celeba"])
def test_ops_counts_positive_and_ordered(name):
    cfg = CONFIGS[name]()
    for layer in cfg.layers:
        assert layer.ops() > 0
        assert layer.ops() == 2 * layer.macs()
    assert cfg.total_ops() == sum(l.ops() for l in cfg.layers)


def test_ops_exact_small_case():
    """Cross-check the closed-form trip count against brute force."""
    from compile.kernels.ref import stride_hole_offsets
    from compile.model import DeconvLayer

    layer = DeconvLayer(2, 3, 4, 2, 1, 5)  # o_h = 10
    f = stride_hole_offsets(4, 2, 1)
    brute = 0
    for kh in range(4):
        for kw in range(4):
            n_oh = len(range(int(f[kh]), 10, 2))
            n_ow = len(range(int(f[kw]), 10, 2))
            brute += n_oh * n_ow
    assert layer.macs() == 2 * 3 * brute


@pytest.mark.parametrize("name", ["mnist", "celeba"])
def test_critic_scalar_output(name):
    cfg = CONFIGS[name]()
    params = init_critic_params(cfg, jax.random.PRNGKey(4))
    x = jax.random.normal(
        jax.random.PRNGKey(5),
        (3, cfg.image_channels, cfg.image_size, cfg.image_size),
    )
    score = critic_apply(params, x)
    assert score.shape == (3, 1)
    assert np.isfinite(np.asarray(score)).all()


def test_flatten_roundtrip():
    cfg = mnist_config()
    params = init_generator_params(cfg, jax.random.PRNGKey(6))
    flat = flatten_params(params)
    assert len(flat) == 2 * len(cfg.layers)
    back = unflatten_params(flat)
    for (w0, b0), (w1, b1) in zip(params, back):
        assert w0 is w1 and b0 is b1
