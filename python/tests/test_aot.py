"""AOT exporter: HLO-text generation and the manifest contract.

Uses the dwarf network from test_train to keep the lowering cheap; the
full-size artifacts are produced by ``make artifacts`` and exercised by
the Rust integration tests.
"""

import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile.aot import export_generator, export_layer, to_hlo_text
from compile.model import (
    DeconvLayer,
    NetworkConfig,
    flatten_params,
    init_generator_params,
)


def tiny_config() -> NetworkConfig:
    layers = (
        DeconvLayer(8, 16, 4, 1, 0, 1),
        DeconvLayer(16, 1, 4, 2, 1, 4),
    )
    return NetworkConfig("tiny", 8, layers, 1, 8, tile=4)


def test_to_hlo_text_basic():
    lowered = jax.jit(lambda x: (x * 2.0 + 1.0,)).lower(
        jax.ShapeDtypeStruct((2, 2), jnp.float32)
    )
    text = to_hlo_text(lowered)
    assert "HloModule" in text
    assert "f32[2,2]" in text


def test_export_generator_writes_hlo(tmp_path):
    cfg = tiny_config()
    params = init_generator_params(cfg, jax.random.PRNGKey(0))
    name, size = export_generator(cfg, params, batch=2, out_dir=str(tmp_path))
    assert name == "tiny_gen_b2.hlo.txt"
    text = (tmp_path / name).read_text()
    assert "HloModule" in text
    assert size == len(text)
    # z + one (w, b) pair per layer as parameters
    n_params = 1 + 2 * len(cfg.layers)
    for i in range(n_params):
        assert f"parameter({i})" in text
    # output is the 1-tuple of an 8x8 image batch
    assert "f32[2,1,8,8]" in text


def test_export_layer_writes_hlo(tmp_path):
    cfg = tiny_config()
    name, _ = export_layer(cfg, 0, batch=1, out_dir=str(tmp_path))
    text = (tmp_path / name).read_text()
    assert "HloModule" in text
    assert "f32[1,16,4,4]" in text  # layer-0 output shape


def test_exported_hlo_has_no_custom_calls(tmp_path):
    """interpret=True must lower to plain HLO (no Mosaic custom-calls),
    otherwise the Rust CPU PJRT client cannot execute the artifact."""
    cfg = tiny_config()
    params = init_generator_params(cfg, jax.random.PRNGKey(0))
    name, _ = export_generator(cfg, params, batch=1, out_dir=str(tmp_path))
    text = (tmp_path / name).read_text()
    assert "custom-call" not in text.lower() or "mosaic" not in text.lower()


def test_full_manifest_if_built():
    """If `make artifacts` has run, validate the manifest contract the
    Rust runtime depends on."""
    root = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")
    manifest_path = os.path.join(root, "manifest.json")
    if not os.path.exists(manifest_path):
        pytest.skip("artifacts not built yet")
    with open(manifest_path) as f:
        manifest = json.load(f)
    assert set(manifest["networks"].keys()) == {"mnist", "celeba"}
    for name, net in manifest["networks"].items():
        for bs, gen in net["generators"].items():
            assert os.path.exists(os.path.join(root, gen)), gen
        for layer_art in net["layer_artifacts"]:
            assert os.path.exists(os.path.join(root, layer_art))
        for wf in net["weights"]:
            w = np.load(os.path.join(root, wf["w"]))
            b = np.load(os.path.join(root, wf["b"]))
            assert w.ndim == 4 and b.ndim == 1
            assert w.shape[1] == b.shape[0]
        truth = np.load(os.path.join(root, net["truth"]))
        assert truth.shape[1] == net["image_channels"]
        assert truth.shape[2] == net["image_size"]
        assert net["param_order"][0] == "z"
        assert len(net["param_order"]) == 1 + 2 * len(net["layers"])
