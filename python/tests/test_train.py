"""WGAN-GP trainer: optimizer correctness, gradient penalty, and a tiny
end-to-end smoke train on a dwarf network (fast on 1 CPU core)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile.model import DeconvLayer, NetworkConfig, init_generator_params
from compile.train import (
    adam_init,
    adam_update,
    gradient_penalty,
    train_wgan_gp,
)


def tiny_config() -> NetworkConfig:
    """8-dim latent → 8×8×1 images; two deconv layers. Training-speed dwarf."""
    layers = (
        DeconvLayer(8, 16, 4, 1, 0, 1),   # 1 -> 4
        DeconvLayer(16, 1, 4, 2, 1, 4),   # 4 -> 8
    )
    return NetworkConfig("tiny", 8, layers, 1, 8, tile=4)


def test_adam_minimizes_quadratic():
    import compile.train as train_mod

    params = {"x": jnp.array([5.0, -3.0])}
    state = adam_init(params)
    loss = lambda p: jnp.sum(p["x"] ** 2)
    l0 = float(loss(params))
    old_lr = train_mod.ADAM["lr"]
    train_mod.ADAM["lr"] = 0.05  # speed up convergence for the test
    try:
        for _ in range(500):
            grads = jax.grad(loss)(params)
            params, state = adam_update(params, grads, state)
    finally:
        train_mod.ADAM["lr"] = old_lr
    assert float(loss(params)) < l0 * 0.01
    assert int(state["t"]) == 500


def test_adam_bias_correction_first_step():
    """After one step with unit gradient, Adam moves by ≈ lr."""
    params = {"x": jnp.array([1.0])}
    state = adam_init(params)
    grads = {"x": jnp.array([1.0])}
    new, _ = adam_update(params, grads, state)
    step = float(params["x"][0] - new["x"][0])
    assert step == pytest.approx(1e-4, rel=1e-3)


def test_gradient_penalty_nonnegative_and_finite():
    from compile.model import init_critic_params

    cfg = tiny_config()
    c_params = init_critic_params(cfg, jax.random.PRNGKey(0))
    real = jnp.asarray(np.random.default_rng(0).normal(size=(4, 1, 8, 8)),
                       dtype=jnp.float32)
    fake = jnp.zeros_like(real)
    gp = gradient_penalty(c_params, real, fake, jax.random.PRNGKey(1))
    assert float(gp) >= 0.0 and np.isfinite(float(gp))


def test_train_smoke_changes_params_and_logs():
    cfg = tiny_config()
    corpus = np.random.default_rng(0).normal(size=(32, 1, 8, 8)).astype(
        np.float32
    )
    corpus = np.tanh(corpus)
    p0 = init_generator_params(cfg, jax.random.PRNGKey(0))
    params, log = train_wgan_gp(
        cfg, steps=2, batch=8, seed=0, log_every=1, verbose=False,
        corpus=corpus,
    )
    # params moved away from the init
    moved = max(
        float(jnp.abs(w - w0).max())
        for (w, _), (w0, _) in zip(params, p0)
    )
    assert moved > 0.0
    assert log["network"] == "tiny"
    assert len(log["history"]) >= 2
    for entry in log["history"]:
        assert np.isfinite(entry["critic_loss"])
        assert np.isfinite(entry["gen_loss"])


def test_train_deterministic_given_seed():
    cfg = tiny_config()
    corpus = np.tanh(
        np.random.default_rng(1).normal(size=(16, 1, 8, 8))
    ).astype(np.float32)
    p1, _ = train_wgan_gp(cfg, steps=1, batch=4, seed=3, verbose=False,
                          corpus=corpus)
    p2, _ = train_wgan_gp(cfg, steps=1, batch=4, seed=3, verbose=False,
                          corpus=corpus)
    for (w1, b1), (w2, b2) in zip(p1, p2):
        np.testing.assert_array_equal(np.asarray(w1), np.asarray(w2))
        np.testing.assert_array_equal(np.asarray(b1), np.asarray(b2))
