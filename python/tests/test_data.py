"""Synthetic corpus substrate: shapes, ranges, determinism, diversity."""

import numpy as np
import pytest

from compile.data import celeba_like, corpus_for, mnist_like


def test_mnist_like_shape_and_range():
    x = mnist_like(8, seed=0)
    assert x.shape == (8, 1, 28, 28)
    assert x.dtype == np.float32
    assert x.min() >= -1.0 and x.max() <= 1.0
    assert x.max() > 0.0  # strokes actually drawn


def test_celeba_like_shape_and_range():
    x = celeba_like(4, seed=0)
    assert x.shape == (4, 3, 64, 64)
    assert x.min() >= -1.0 and x.max() <= 1.0


def test_determinism():
    a = mnist_like(4, seed=7)
    b = mnist_like(4, seed=7)
    np.testing.assert_array_equal(a, b)
    c = celeba_like(2, seed=3)
    d = celeba_like(2, seed=3)
    np.testing.assert_array_equal(c, d)


def test_seed_changes_samples():
    a = mnist_like(4, seed=1)
    b = mnist_like(4, seed=2)
    assert np.abs(a - b).max() > 0.1


def test_sample_diversity():
    """Samples within one corpus must not all be identical (MMD needs a
    non-degenerate P_g)."""
    x = mnist_like(16, seed=0)
    diffs = [np.abs(x[i] - x[0]).max() for i in range(1, 16)]
    assert max(diffs) > 0.5
    y = celeba_like(8, seed=0)
    diffs = [np.abs(y[i] - y[0]).max() for i in range(1, 8)]
    assert max(diffs) > 0.2


def test_corpus_for_dispatch():
    assert corpus_for("mnist", 2).shape == (2, 1, 28, 28)
    assert corpus_for("celeba", 2).shape == (2, 3, 64, 64)
    with pytest.raises(ValueError):
        corpus_for("imagenet", 2)
