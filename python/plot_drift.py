"""Plot a loadtest latency-drift CSV (``edgedcnn loadtest --drift-csv``).

The CSV is the final trial's windowed latency histogram shards, one row
per elapsed-time window::

    window_start_s,count,p50_s,p99_s
    0,128,0.0021,0.0094
    1,131,0.0022,0.0101
    ...

This script draws p50 and p99 per window on one axis (milliseconds) —
the picture that makes latency drift over a run visible at a glance:
flat lines mean a stationary system, a rising p99 with a flat p50 means
tail degradation (queue buildup, thermal throttling in the GPU model).

Usage::

    edgedcnn loadtest --smoke --drift-csv drift.csv
    python python/plot_drift.py drift.csv --out drift.png

Requires matplotlib only at plot time; ``--summary`` prints a text table
from the same CSV with no third-party imports at all.
"""

from __future__ import annotations

import argparse
import csv
import sys


def read_drift(path: str) -> list[dict[str, float]]:
    """Parse the drift CSV into one dict per window, skipping rows with
    no samples (their quantiles are meaningless)."""
    rows: list[dict[str, float]] = []
    with open(path, newline="") as f:
        reader = csv.DictReader(f)
        required = {"window_start_s", "count", "p50_s", "p99_s"}
        missing = required - set(reader.fieldnames or [])
        if missing:
            raise SystemExit(
                f"{path}: not a drift CSV (missing columns: "
                f"{', '.join(sorted(missing))})"
            )
        for row in reader:
            count = int(float(row["count"]))
            if count == 0:
                continue
            rows.append(
                {
                    "window_start_s": float(row["window_start_s"]),
                    "count": count,
                    "p50_s": float(row["p50_s"]),
                    "p99_s": float(row["p99_s"]),
                }
            )
    if not rows:
        raise SystemExit(f"{path}: no windows with samples")
    return rows


def print_summary(rows: list[dict[str, float]]) -> None:
    print(f"{'window_s':>9} {'count':>7} {'p50_ms':>9} {'p99_ms':>9}")
    for r in rows:
        print(
            f"{r['window_start_s']:>9.1f} {r['count']:>7d} "
            f"{r['p50_s'] * 1e3:>9.3f} {r['p99_s'] * 1e3:>9.3f}"
        )
    worst = max(rows, key=lambda r: r["p99_s"])
    print(
        f"worst window: t={worst['window_start_s']:.1f}s "
        f"p99={worst['p99_s'] * 1e3:.3f}ms over {worst['count']} samples"
    )


def plot(rows: list[dict[str, float]], out: str) -> None:
    try:
        import matplotlib

        matplotlib.use("Agg")  # headless CI
        import matplotlib.pyplot as plt
    except ImportError:
        raise SystemExit(
            "matplotlib is not installed; use --summary for the text "
            "table, or install matplotlib to render the PNG"
        )
    t = [r["window_start_s"] for r in rows]
    p50 = [r["p50_s"] * 1e3 for r in rows]
    p99 = [r["p99_s"] * 1e3 for r in rows]
    fig, ax = plt.subplots(figsize=(8, 4))
    ax.plot(t, p99, marker="o", markersize=3, label="p99", color="tab:red")
    ax.plot(t, p50, marker="o", markersize=3, label="p50", color="tab:blue")
    ax.set_xlabel("elapsed time (s)")
    ax.set_ylabel("request latency (ms)")
    ax.set_title("latency drift per window (edgedcnn loadtest)")
    ax.legend()
    ax.grid(True, alpha=0.3)
    fig.tight_layout()
    fig.savefig(out, dpi=120)
    print(f"wrote {out} ({len(rows)} windows)")


def main(argv: list[str] | None = None) -> None:
    parser = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    parser.add_argument("csv", help="drift CSV from loadtest --drift-csv")
    parser.add_argument(
        "--out", default="drift.png", help="output PNG path (default: %(default)s)"
    )
    parser.add_argument(
        "--summary",
        action="store_true",
        help="print a text table instead of rendering a PNG",
    )
    args = parser.parse_args(argv)
    rows = read_drift(args.csv)
    if args.summary:
        print_summary(rows)
    else:
        plot(rows, args.out)


if __name__ == "__main__":
    main(sys.argv[1:])
